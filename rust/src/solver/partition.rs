//! Bucket-to-worker partitioning — the heart of the paper's §3
//! "Multi-threaded Implementation".
//!
//! * **Static**: buckets are split into contiguous chunks once; each worker
//!   reshuffles *within* its own chunk every epoch. This is the CoCoA
//!   default and what a distributed system must do (moving data is
//!   expensive) — and it measurably inflates epochs-to-converge (Fig. 2b,
//!   Fig. 5a).
//! * **Dynamic** (the paper's novel scheme): shuffle *all* buckets globally
//!   every epoch and deal them out to workers round-robin, so each worker
//!   sees a fresh random subset each epoch. Free in shared memory because
//!   only indices move, never data.

use crate::util::Rng;

/// Partitioning scheme for the replica-based solvers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Partitioning {
    Static,
    Dynamic,
}

/// Assignment of bucket ids to `workers` for one epoch.
#[derive(Clone, Debug)]
pub struct EpochAssignment {
    /// `per_worker[t]` = bucket ids worker `t` processes, in order.
    pub per_worker: Vec<Vec<u32>>,
}

impl EpochAssignment {
    pub fn total(&self) -> usize {
        self.per_worker.iter().map(|w| w.len()).sum()
    }
}

/// Epoch-by-epoch partitioner over `num_buckets` buckets and `workers`
/// workers. Holds the static chunks (computed once) and the scratch
/// permutation reused across epochs to avoid per-epoch allocation.
pub struct Partitioner {
    scheme: Partitioning,
    workers: usize,
    /// Static chunk of each worker (contiguous ranges), fixed at creation.
    static_chunks: Vec<Vec<u32>>,
    /// Scratch permutation for the dynamic scheme.
    perm: Vec<u32>,
}

impl Partitioner {
    pub fn new(scheme: Partitioning, num_buckets: usize, workers: usize) -> Self {
        assert!(workers >= 1);
        // contiguous near-equal chunks, like a distributed loader would
        let base = num_buckets / workers;
        let extra = num_buckets % workers;
        let mut static_chunks = Vec::with_capacity(workers);
        let mut next = 0u32;
        for t in 0..workers {
            let len = base + usize::from(t < extra);
            static_chunks.push((next..next + len as u32).collect());
            next += len as u32;
        }
        Partitioner {
            scheme,
            workers,
            static_chunks,
            perm: (0..num_buckets as u32).collect(),
        }
    }

    /// Produce this epoch's assignment. `rng` advances every epoch so
    /// consecutive epochs see different permutations.
    pub fn assign(&mut self, rng: &mut Rng) -> EpochAssignment {
        match self.scheme {
            Partitioning::Static => {
                // shuffle order *within* each worker's fixed chunk
                let mut per_worker = self.static_chunks.clone();
                for chunk in per_worker.iter_mut() {
                    rng.shuffle(chunk);
                }
                EpochAssignment { per_worker }
            }
            Partitioning::Dynamic => {
                rng.shuffle(&mut self.perm);
                // deal contiguous slices of the fresh global permutation —
                // equal work per worker, fully re-randomized membership
                let n = self.perm.len();
                let base = n / self.workers;
                let extra = n % self.workers;
                let mut per_worker = Vec::with_capacity(self.workers);
                let mut off = 0;
                for t in 0..self.workers {
                    let len = base + usize::from(t < extra);
                    per_worker.push(self.perm[off..off + len].to_vec());
                    off += len;
                }
                EpochAssignment { per_worker }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn is_partition(a: &EpochAssignment, num_buckets: usize) {
        let mut seen = vec![false; num_buckets];
        for w in &a.per_worker {
            for &b in w {
                assert!(!seen[b as usize], "bucket {b} assigned twice");
                seen[b as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "some bucket unassigned");
    }

    #[test]
    fn static_is_partition_and_membership_fixed() {
        let mut p = Partitioner::new(Partitioning::Static, 100, 4);
        let mut rng = Rng::new(1);
        let a1 = p.assign(&mut rng);
        let a2 = p.assign(&mut rng);
        is_partition(&a1, 100);
        is_partition(&a2, 100);
        for t in 0..4 {
            let mut m1 = a1.per_worker[t].clone();
            let mut m2 = a2.per_worker[t].clone();
            m1.sort_unstable();
            m2.sort_unstable();
            assert_eq!(m1, m2, "static membership must not move across epochs");
            assert_ne!(
                a1.per_worker[t], a2.per_worker[t],
                "order must reshuffle within the chunk"
            );
        }
    }

    #[test]
    fn dynamic_is_partition_and_membership_moves() {
        let mut p = Partitioner::new(Partitioning::Dynamic, 100, 4);
        let mut rng = Rng::new(2);
        let a1 = p.assign(&mut rng);
        let a2 = p.assign(&mut rng);
        is_partition(&a1, 100);
        is_partition(&a2, 100);
        // membership should differ between epochs for at least one worker
        let moved = (0..4).any(|t| {
            let mut m1 = a1.per_worker[t].clone();
            let mut m2 = a2.per_worker[t].clone();
            m1.sort_unstable();
            m2.sort_unstable();
            m1 != m2
        });
        assert!(moved, "dynamic partitioning must re-deal buckets");
    }

    #[test]
    fn balanced_loads() {
        for scheme in [Partitioning::Static, Partitioning::Dynamic] {
            let mut p = Partitioner::new(scheme, 103, 4);
            let mut rng = Rng::new(3);
            let a = p.assign(&mut rng);
            let sizes: Vec<usize> = a.per_worker.iter().map(|w| w.len()).collect();
            assert_eq!(sizes.iter().sum::<usize>(), 103);
            assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
        }
    }

    #[test]
    fn single_worker_degenerates_to_shuffle() {
        let mut p = Partitioner::new(Partitioning::Dynamic, 10, 1);
        let mut rng = Rng::new(4);
        let a = p.assign(&mut rng);
        assert_eq!(a.per_worker.len(), 1);
        is_partition(&a, 10);
    }

    #[test]
    fn more_workers_than_buckets() {
        let mut p = Partitioner::new(Partitioning::Dynamic, 3, 8);
        let mut rng = Rng::new(5);
        let a = p.assign(&mut rng);
        is_partition(&a, 3);
        assert_eq!(a.per_worker.iter().filter(|w| !w.is_empty()).count(), 3);
    }
}
