//! The "domesticated" multi-threaded trainer (§3, "Multi-threaded
//! Implementation"): data parallelism with per-thread replicas of the
//! shared vector instead of wild shared writes.
//!
//! Per epoch:
//! 1. partition the (buckets of) examples across `T` workers — statically
//!    (CoCoA-style, the Fig. 5a baseline) or **dynamically**, the paper's
//!    novel scheme: re-shuffle the global bucket permutation and re-deal it
//!    every epoch;
//! 2. every worker clones the global `v` into a private replica and runs
//!    exact SDCA steps on its own coordinates against that replica, using
//!    the CoCoA-safe local curvature (`n_eff = n/T`, i.e. σ′ = T);
//! 3. at each of `merges_per_epoch` barriers the workers' replica deltas
//!    are reduced into the global `v` (exact, since `α` updates are
//!    disjoint) and fresh replicas are taken.
//!
//! Convergence is checked on the merged model exactly as in the sequential
//! solver, so "epochs to converge" is directly comparable across variants.

use crate::data::shard::{RunLayout, Shard};
use crate::data::{DataMatrix, Dataset, LayoutPolicy, ShardedLayout};
use crate::glm::{ModelState, Objective};
use crate::metrics::{EpochStats, RunRecord};
use crate::obs::{self, EventKind};
use crate::solver::exec::Executor;
use crate::solver::seq::sdca_delta_at;
use crate::solver::tune::{EpochTuner, Knob, TuneCaps};
use crate::solver::{
    kernel, BucketPolicy, Buckets, ConvergenceMonitor, Partitioning, SolverConfig, TrainOutput,
};
use crate::solver::partition::Partitioner;
use crate::util::atomic::{atomic_vec, snapshot, AtomicF64};
use crate::util::{Rng, Timer};

/// Production entry point: workers come from the configured
/// [`ExecPolicy`](crate::solver::ExecPolicy) — by default a persistent
/// NUMA-aware [`WorkerPool`](crate::solver::WorkerPool) created here,
/// once, and reused for every merge round of the run.
pub fn train_domesticated<M: DataMatrix>(ds: &Dataset<M>, cfg: &SolverConfig) -> TrainOutput {
    let topo = cfg
        .topology
        .clone()
        .unwrap_or_else(crate::sysinfo::Topology::detect);
    let exec = cfg.build_executor(&topo);
    train_domesticated_exec(ds, cfg, &exec)
}

/// One worker's share of an epoch round: exact SDCA steps on its own
/// coordinates against a private replica, under the CoCoA+ σ′-scaled local
/// subproblem (σ′ = K, updates *added* at merges — the provably-safe
/// aggregation for K data-parallel workers).
///
/// The replica tracks `u = v_global + σ′·A·Δα_local`: each step reads its
/// margin from `u` and solves the 1-D problem with curvature
/// `σ′·‖x‖²/(λn)` (passed as `n_eff = n/σ′`), so the worker is exactly
/// conservative enough that the *sum* of all workers' deltas cannot
/// overshoot. Returns `A·Δα_local = (u − v_global)/σ′`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn worker_round<M: DataMatrix>(
    ds: &Dataset<M>,
    obj: &Objective,
    buckets: &Buckets,
    my_buckets: &[u32],
    shard: Option<&Shard>,
    alpha: &[AtomicF64],
    v_global: &[f64],
    inv_lambda_n: f64,
    n_eff: usize,
    sigma: f64,
) -> Vec<f64> {
    let mut u = v_global.to_vec();
    if let Some(sh) = shard {
        // fused interleaved kernels; the worker's own (re-dealt) bucket
        // list drives the one-ahead software prefetch
        for (i, &b) in my_buckets.iter().enumerate() {
            if let Some(&nb) = my_buckets.get(i + 1) {
                sh.prefetch_bucket(nb as usize);
            }
            kernel::run_bucket_replica(
                sh,
                obj,
                buckets.range(b as usize),
                alpha,
                &mut u,
                &ds.y,
                ds.norms(),
                inv_lambda_n,
                n_eff,
                sigma,
            );
        }
    } else {
        // source-matrix walk: one cursor per worker round amortizes the
        // segment lookup of the chunked dataset across its bucket list
        let mut cur = ds.x.col_cursor();
        for &b in my_buckets {
            for j in buckets.range(b as usize) {
                let a = alpha[j].load();
                let delta = sdca_delta_at(&mut cur, ds, obj, j, a, &u, inv_lambda_n, n_eff);
                if delta != 0.0 {
                    alpha[j].store(a + delta);
                    cur.axpy(j, sigma * delta, &mut u);
                }
            }
        }
    }
    // return A·Δα = (u − v_global)/σ′
    for (l, g) in u.iter_mut().zip(v_global.iter()) {
        *l = (*l - g) / sigma;
    }
    u
}

/// Core implementation, parameterized over the execution strategy (see
/// [`Executor`] — `Sequential` reproduces the identical model on one core).
pub fn train_domesticated_exec<M: DataMatrix>(
    ds: &Dataset<M>,
    cfg: &SolverConfig,
    exec: &Executor,
) -> TrainOutput {
    let n = ds.n();
    let t_workers = cfg.threads.max(1);
    let obj = cfg.obj;
    let inv_lambda_n = 1.0 / (obj.lambda() * n as f64);
    // CoCoA+ local subproblem scaling σ′ (see SigmaPolicy): the 1-D
    // solver sees curvature scaled by σ′, i.e. n_eff = n/σ′.
    let sigma_max = t_workers as f64;
    let mut sigma = match cfg.sigma {
        crate::solver::SigmaPolicy::Safe => sigma_max,
        crate::solver::SigmaPolicy::Adaptive => (sigma_max / 4.0).max(1.0),
        crate::solver::SigmaPolicy::Fixed(s) => s.max(1.0),
    };
    let adaptive = matches!(cfg.sigma, crate::solver::SigmaPolicy::Adaptive);
    // ratcheting floor: every backtrack proves the current σ′ was too
    // aggressive, so relaxation never goes below the last unstable point
    // again — reverts are finite (≤ log₂K) and the tail is stable
    let mut sigma_floor = 1.0f64;

    let mut bucket_size = cfg.bucket.resolve_host(n);
    let mut buckets = Buckets::new(n, bucket_size);
    // One global interleaved shard, shared read-only by every worker:
    // dynamic re-deals move bucket *ids* between workers, never entries,
    // so the encoding is built exactly once per run — or not at all, when
    // the caller's cached layout already has the right geometry. The
    // tuner may flip `use_interleaved` (bit-free) or rebuild the shard at
    // an epoch boundary when it re-buckets.
    let mut use_interleaved = cfg.layout == LayoutPolicy::Interleaved;
    let mut layout = RunLayout::resolve(
        use_interleaved,
        cfg.layout_cache.as_ref(),
        |l| l.matches_single(n, ds.d(), ds.x.nnz(), bucket_size),
        || ShardedLayout::single(&ds.x, &buckets),
    );
    // `eff_workers` is the number of per-epoch jobs the partitioner deals
    // to (the tuner may retire workers on persistent imbalance); the σ′
    // machinery stays keyed to the configured `t_workers`, which remains
    // a safe upper bound when fewer replicas actually run.
    let mut eff_workers = t_workers;
    let mut partitioning = cfg.partition;
    let mut partitioner = Partitioner::new(partitioning, buckets.count(), eff_workers);
    let rounds = cfg.resolve_merges(ds);

    let init = crate::solver::initial_state(cfg, ds);
    let alpha: Vec<AtomicF64> = atomic_vec(n);
    for (slot, &a) in alpha.iter().zip(init.alpha.iter()) {
        if a != 0.0 {
            slot.store(a);
        }
    }
    let mut rng = Rng::new(cfg.seed);
    let mut mon = ConvergenceMonitor::new(n, cfg.tol, cfg.divergence_factor);
    if cfg.warm_start.is_some() {
        mon.seed(&init.alpha);
    }
    let mut v_global = init.v;

    let total = Timer::start();
    let mut epochs = Vec::new();
    let mut converged = false;
    // dual value of the merged model — the adaptive-σ backtracking signal
    // (D(0) = 0 for all three objectives at the cold start; a warm start
    // resumes the backtracking baseline from its own dual value)
    let mut prev_dual = if adaptive && cfg.warm_start.is_some() {
        let st = ModelState {
            alpha: snapshot(&alpha),
            v: v_global.clone(),
        };
        crate::glm::gap::dual_value(ds, &obj, &st)
    } else {
        0.0f64
    };
    let label = format!(
        "dom-{}(bucket={bucket_size})",
        match cfg.partition {
            Partitioning::Static => "static",
            Partitioning::Dynamic => "dynamic",
        }
    );
    // per-epoch convergence telemetry: reuses rel/gap/wall_s below, adds
    // no clock read or gap computation of its own
    let mut conv = obs::ConvergenceTrace::new(label.clone(), t_workers);
    let caps = TuneCaps {
        bucket: matches!(cfg.bucket, BucketPolicy::Auto),
        layout: true,
        workers: true,
    };
    let mut tuner = EpochTuner::for_run(
        cfg.tune,
        caps,
        &label,
        bucket_size,
        use_interleaved,
        eff_workers,
        partitioning == Partitioning::Dynamic,
    );
    let epoch_ctr = obs::registry().counter("solver.epochs");
    let epoch_wall_us = obs::registry().histogram("solver.epoch_wall_us");
    for epoch in 1..=cfg.max_epochs {
        let t = Timer::start();
        obs::emit(EventKind::EpochBegin, obs::CLASS_NONE, 0, epoch as u64);
        // armed fault plans fire here (coordinator thread, before any
        // dispatch) so an injected panic unwinds cleanly through the epoch
        crate::fault::poke(crate::fault::FaultSite::Epoch);
        // cooperative cancellation: the once-per-epoch checkpoint
        if let Some(c) = &cfg.cancel {
            c.checkpoint(&label, epoch);
        }
        let shard = if use_interleaved { layout.shard(0) } else { None };
        // snapshot for possible backtracking
        let snap_state = adaptive.then(|| (snapshot(&alpha), v_global.clone()));
        let n_eff = ((n as f64 / sigma).round() as usize).max(1);
        let assignment = partitioner.assign(&mut rng);
        for round in 0..rounds {
            // each worker takes the `round`-th segment of its epoch list
            let jobs: Vec<_> = (0..eff_workers)
                .map(|tid| {
                    let list = &assignment.per_worker[tid];
                    let seg = segment(list, round, rounds);
                    let (ds, obj, buckets, alpha, v_ref) =
                        (&*ds, &obj, &buckets, &alpha[..], &v_global[..]);
                    move || {
                        worker_round(
                            ds, obj, buckets, seg, shard, alpha, v_ref, inv_lambda_n, n_eff,
                            sigma,
                        )
                    }
                })
                .collect();
            let deltas = exec.run(jobs);
            for dv in &deltas {
                crate::util::axpy(1.0, dv, &mut v_global);
            }
        }
        let mut reverted = false;
        if adaptive {
            let st = ModelState {
                alpha: snapshot(&alpha),
                v: v_global.clone(),
            };
            let dual = crate::glm::gap::dual_value(ds, &obj, &st);
            if dual + 1e-12 * dual.abs().max(1.0) < prev_dual && sigma < sigma_max {
                // merged step overshot: revert the epoch, damp harder
                let (a_snap, v_snap) = snap_state.unwrap();
                for (slot, val) in alpha.iter().zip(&a_snap) {
                    slot.store(*val);
                }
                v_global = v_snap;
                sigma_floor = (sigma * 2.0).min(sigma_max);
                sigma = sigma_floor;
                reverted = true;
            } else {
                prev_dual = dual;
                // progress was safe: relax toward the unscaled subproblem
                sigma = (sigma / 1.15).max(sigma_floor);
            }
        }
        let a_snap = snapshot(&alpha);
        // a reverted epoch made no (accepted) progress — it must not trip
        // the relative-change convergence test
        let rel = if reverted {
            f64::INFINITY
        } else {
            mon.observe(&a_snap)
        };
        let gap = if cfg.gap_tol.is_some() && epoch % cfg.gap_check_every == 0 {
            let st = ModelState {
                alpha: a_snap.clone(),
                v: v_global.clone(),
            };
            Some(crate::glm::duality_gap(ds, &obj, &st).gap)
        } else {
            None
        };
        let wall_s = t.elapsed_s();
        epochs.push(EpochStats {
            epoch,
            wall_s,
            rel_change: rel,
            gap,
            primal: None,
        });
        let pool_stats = exec.stats();
        conv.record(
            epoch,
            wall_s,
            rel,
            gap,
            pool_stats.as_ref().map(|s| s.imbalance()),
            pool_stats.as_ref().map(|s| s.total_busy_s()),
        );
        // Epoch-boundary tuning: feed the point just recorded, apply any
        // decisions before the next epoch starts.
        for d in tuner.observe(conv.points.last().expect("recorded this epoch")) {
            match d.knob {
                Knob::Layout => {
                    use_interleaved = d.to == "interleaved";
                    if use_interleaved && layout.shard(0).is_none() {
                        layout = RunLayout::resolve(true, None, |_| false, || {
                            ShardedLayout::single(&ds.x, &buckets)
                        });
                    }
                }
                Knob::Bucket => {
                    if let Ok(nb) = d.to.parse::<usize>() {
                        bucket_size = nb.max(1);
                        buckets = Buckets::new(n, bucket_size);
                        if use_interleaved {
                            layout = RunLayout::resolve(true, None, |_| false, || {
                                ShardedLayout::single(&ds.x, &buckets)
                            });
                        }
                        partitioner = Partitioner::new(partitioning, buckets.count(), eff_workers);
                    }
                }
                Knob::Steal => {
                    partitioning = Partitioning::Dynamic;
                    partitioner = Partitioner::new(partitioning, buckets.count(), eff_workers);
                }
                Knob::Workers => {
                    if let Ok(w) = d.to.parse::<usize>() {
                        eff_workers = w.max(1);
                        partitioner = Partitioner::new(partitioning, buckets.count(), eff_workers);
                    }
                }
            }
        }
        epoch_ctr.inc();
        epoch_wall_us.record((wall_s * 1e6) as u64);
        obs::emit(EventKind::EpochEnd, obs::CLASS_NONE, 0, epoch as u64);
        if mon.converged() || gap.map(|g| g < cfg.gap_tol.unwrap()).unwrap_or(false) {
            converged = true;
            break;
        }
    }

    let st = ModelState {
        alpha: snapshot(&alpha),
        v: v_global,
    };
    let record = RunRecord {
        solver: label,
        threads: t_workers,
        epochs,
        converged,
        diverged: false,
        total_wall_s: total.elapsed_s(),
    };
    TrainOutput::assemble(ds, &obj, st, record)
        .with_convergence(conv)
        .with_tune_log(tuner.finish())
}

/// `round`-th of `rounds` near-equal segments of a worker's bucket list.
pub(crate) fn segment(list: &[u32], round: usize, rounds: usize) -> &[u32] {
    let n = list.len();
    let base = n / rounds;
    let extra = n % rounds;
    let lo = round * base + round.min(extra);
    let len = base + usize::from(round < extra);
    &list[lo..lo + len]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::solver::Variant;

    fn cfg(lambda: f64, threads: usize) -> SolverConfig {
        SolverConfig::new(Objective::Logistic { lambda })
            .with_variant(Variant::Domesticated)
            .with_threads(threads)
            .with_tol(1e-5)
            .with_max_epochs(500)
    }

    #[test]
    fn segments_partition_list() {
        let list: Vec<u32> = (0..10).collect();
        let mut all = Vec::new();
        for r in 0..3 {
            all.extend_from_slice(segment(&list, r, 3));
        }
        assert_eq!(all, list);
    }

    #[test]
    fn converges_multithreaded_dense() {
        let ds = synthetic::dense_classification(500, 20, 1);
        let out = train_domesticated(&ds, &cfg(1.0 / 500.0, 4));
        assert!(out.converged, "epochs={}", out.epochs_run);
        assert!(out.final_gap < 1e-3, "gap={}", out.final_gap);
    }

    #[test]
    fn converges_multithreaded_sparse() {
        let ds = synthetic::sparse_classification(600, 150, 0.05, 2);
        let out = train_domesticated(&ds, &cfg(1.0 / 600.0, 8));
        assert!(out.converged);
        assert!(out.final_gap < 1e-3);
    }

    #[test]
    fn threads_and_sequential_executor_identical() {
        let ds = synthetic::dense_classification(300, 12, 3);
        let c = cfg(1e-3, 4).with_max_epochs(20).with_tol(0.0);
        let a = train_domesticated_exec(&ds, &c, &Executor::Threads);
        let b = train_domesticated_exec(&ds, &c, &Executor::Sequential);
        assert_eq!(a.state.alpha, b.state.alpha, "executors must be bitwise identical");
        assert_eq!(a.state.v, b.state.v);
    }

    #[test]
    fn static_needs_more_epochs_than_dynamic() {
        // the paper's Fig 5a effect, at small scale
        let ds = synthetic::dense_classification(2000, 30, 4);
        let base = cfg(1.0 / 2000.0, 8).with_tol(1e-4);
        let dynamic = train_domesticated(&ds, &base.clone().with_partition(Partitioning::Dynamic));
        let statik = train_domesticated(&ds, &base.with_partition(Partitioning::Static));
        assert!(dynamic.converged && statik.converged);
        assert!(
            dynamic.epochs_run <= statik.epochs_run,
            "dynamic {} vs static {}",
            dynamic.epochs_run,
            statik.epochs_run
        );
    }

    #[test]
    fn v_consistent_after_merges() {
        let ds = synthetic::dense_classification(200, 10, 5);
        let mut c = cfg(0.01, 3);
        c.merges_per_epoch = 4;
        let out = train_domesticated(&ds, &c);
        assert!(out.state.v_drift(&ds) < 1e-8, "drift={}", out.state.v_drift(&ds));
    }

    #[test]
    fn same_quality_as_sequential() {
        let ds = synthetic::dense_classification(400, 15, 6);
        let obj = Objective::Logistic { lambda: 1e-3 };
        let seq = crate::solver::seq::train_sequential(
            &ds,
            &SolverConfig::new(obj).with_tol(1e-7).with_max_epochs(1000),
        );
        let dom = train_domesticated(&ds, &cfg(1e-3, 4).with_tol(1e-7).with_max_epochs(1000));
        let dist = crate::util::rel_change(&seq.weights(&obj), &dom.weights(&obj));
        assert!(dist < 5e-3, "solutions differ: {dist}");
    }
}
