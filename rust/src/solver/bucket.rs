//! The bucket optimization (§3, "Single-Threaded Implementation").
//!
//! SDCA visits `α` in random order; each visit touches 8 bytes of a 64- or
//! 128-byte cache line, so a cold model vector costs a full line per step.
//! Processing a *bucket* of consecutive examples per randomized index
//! (i) uses every `α` slot of each fetched line, (ii) divides the shuffle
//! length by the bucket size, and (iii) gives the hardware prefetcher a
//! sequential stream of example columns.
//!
//! The trade-off is reduced sampling randomness, so the paper gates the
//! optimization on whether the model vector actually misses the LLC:
//! buckets are enabled only when `n · 8B > LLC` (the "~500k entries"
//! cut-off quoted in §3 corresponds to a ~4 MiB L3 slice per socket).

use crate::sysinfo;

/// How to choose the bucket size.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BucketPolicy {
    /// Paper behaviour: `cache_line / 8` when `α` misses the LLC, else 1.
    Auto,
    /// Fixed size (1 = off).
    Fixed(usize),
    /// Never bucket (baseline for the Fig. 5b ablation).
    Off,
}

impl BucketPolicy {
    /// Resolve to a concrete bucket size for a model vector of `n` f64
    /// entries on the current (or injected) cache geometry.
    pub fn resolve(&self, n: usize, cache_line: usize, llc_bytes: usize) -> usize {
        match *self {
            BucketPolicy::Off => 1,
            BucketPolicy::Fixed(k) => k.max(1),
            BucketPolicy::Auto => {
                let model_bytes = n * std::mem::size_of::<f64>();
                if model_bytes > llc_bytes {
                    (cache_line / std::mem::size_of::<f64>()).max(1)
                } else {
                    1
                }
            }
        }
    }

    /// Resolve against the host geometry (sysfs probes).
    pub fn resolve_host(&self, n: usize) -> usize {
        self.resolve(n, sysinfo::cache_line_size(), sysinfo::llc_size())
    }
}

/// Bucketed index space over `n` examples: bucket `b` covers examples
/// `[b·size, min((b+1)·size, n))`. The final bucket may be short.
#[derive(Clone, Debug)]
pub struct Buckets {
    n: usize,
    size: usize,
}

impl Buckets {
    pub fn new(n: usize, size: usize) -> Self {
        assert!(size >= 1);
        Buckets { n, size }
    }

    #[inline]
    pub fn size(&self) -> usize {
        self.size
    }

    /// Number of buckets (`⌈n/size⌉`).
    #[inline]
    pub fn count(&self) -> usize {
        self.n.div_ceil(self.size)
    }

    /// Example range of bucket `b`.
    #[inline]
    pub fn range(&self, b: usize) -> std::ops::Range<usize> {
        let lo = b * self.size;
        let hi = ((b + 1) * self.size).min(self.n);
        lo..hi
    }

    /// Identity permutation of bucket ids, ready for shuffling.
    pub fn ids(&self) -> Vec<u32> {
        (0..self.count() as u32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_gates_on_llc() {
        let line = 64;
        let llc = 1 << 20; // 1 MiB
        // 100k entries = 800 kB < 1 MiB → off
        assert_eq!(BucketPolicy::Auto.resolve(100_000, line, llc), 1);
        // 1M entries = 8 MB > 1 MiB → line/8 = 8
        assert_eq!(BucketPolicy::Auto.resolve(1_000_000, line, llc), 8);
        // POWER9-style 128B lines → 16
        assert_eq!(BucketPolicy::Auto.resolve(1_000_000, 128, llc), 16);
    }

    #[test]
    fn fixed_and_off() {
        assert_eq!(BucketPolicy::Fixed(16).resolve(10, 64, 1 << 30), 16);
        assert_eq!(BucketPolicy::Fixed(0).resolve(10, 64, 1 << 30), 1);
        assert_eq!(BucketPolicy::Off.resolve(usize::MAX / 16, 64, 1), 1);
    }

    #[test]
    fn bucket_ranges_cover_exactly() {
        let b = Buckets::new(103, 8);
        assert_eq!(b.count(), 13);
        let mut seen = vec![false; 103];
        for id in 0..b.count() {
            for j in b.range(id) {
                assert!(!seen[j], "example {j} covered twice");
                seen[j] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
        assert_eq!(b.range(12), 96..103); // short tail
    }

    #[test]
    fn size_one_is_identity() {
        let b = Buckets::new(5, 1);
        assert_eq!(b.count(), 5);
        assert_eq!(b.range(3), 3..4);
    }

    #[test]
    fn shuffle_cost_reduction() {
        // the point of the optimization: 8× fewer indices to shuffle
        let b = Buckets::new(1_000_000, 8);
        assert_eq!(b.count(), 125_000);
    }
}
