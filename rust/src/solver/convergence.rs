//! Stopping rules shared by every solver variant.
//!
//! Primary criterion (the paper's): relative change of the learned model
//! between consecutive epochs below `tol`. Secondary: optional duality-gap
//! threshold. Divergence detection: the wild solver at high thread counts
//! can drive the dual variables to garbage (paper Fig. 1a red entries) —
//! we flag a run as diverged when `α` leaves the dual domain by a large
//! margin or the model norm explodes.

use crate::glm::Objective;

/// Tracks the previous-epoch model and evaluates stopping conditions.
pub struct ConvergenceMonitor {
    prev_alpha: Vec<f64>,
    tol: f64,
    divergence_factor: f64,
    initial_scale: Option<f64>,
    pub last_rel_change: f64,
}

impl ConvergenceMonitor {
    pub fn new(n: usize, tol: f64, divergence_factor: f64) -> Self {
        ConvergenceMonitor {
            prev_alpha: vec![0.0; n],
            tol,
            divergence_factor,
            initial_scale: None,
            last_rel_change: f64::INFINITY,
        }
    }

    /// Seed the previous-epoch model (warm starts): the first epoch's
    /// relative change is then measured against the warm state instead of
    /// zero, so a refit of an already-converged model can stop after one
    /// epoch. The divergence scale is still taken at the first `observe`.
    pub fn seed(&mut self, alpha: &[f64]) {
        self.prev_alpha.copy_from_slice(alpha);
    }

    /// Feed the end-of-epoch model; returns the relative change.
    pub fn observe(&mut self, alpha: &[f64]) -> f64 {
        let rc = crate::util::rel_change(alpha, &self.prev_alpha);
        self.prev_alpha.copy_from_slice(alpha);
        self.last_rel_change = rc;
        let norm = crate::util::norm_sq(alpha).sqrt();
        if self.initial_scale.is_none() && norm > 0.0 {
            self.initial_scale = Some(norm.max(1.0));
        }
        rc
    }

    /// Converged under the paper's criterion?
    pub fn converged(&self) -> bool {
        self.last_rel_change < self.tol
    }

    /// Diverged? (model norm exploded relative to its first-epoch scale, or
    /// went non-finite.)
    pub fn diverged(&self, alpha: &[f64]) -> bool {
        let norm = crate::util::norm_sq(alpha).sqrt();
        if !norm.is_finite() {
            return true;
        }
        match self.initial_scale {
            Some(s) => norm > s * self.divergence_factor,
            None => false,
        }
    }

    /// Dual-domain sanity for constrained objectives: fraction of
    /// coordinates outside `y·α ∈ [0,1]` (should be exactly 0 for any
    /// correct solver; wild lost updates can violate it).
    pub fn domain_violation(obj: &Objective, alpha: &[f64], y: &[f64]) -> f64 {
        match obj {
            Objective::Ridge { .. } => 0.0,
            _ => {
                let bad = alpha
                    .iter()
                    .zip(y.iter())
                    .filter(|(&a, &yy)| {
                        let s = a * yy;
                        !(-1e-9..=1.0 + 1e-9).contains(&s)
                    })
                    .count();
                bad as f64 / alpha.len().max(1) as f64
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detects_convergence() {
        let mut m = ConvergenceMonitor::new(3, 1e-3, 1e3);
        m.observe(&[1.0, 1.0, 1.0]);
        assert!(!m.converged()); // first epoch: change from zero is 100%
        m.observe(&[1.0, 1.0, 1.0 + 1e-6]);
        assert!(m.converged());
    }

    #[test]
    fn seeded_monitor_can_converge_on_first_epoch() {
        let mut m = ConvergenceMonitor::new(3, 1e-3, 1e3);
        m.seed(&[1.0, 1.0, 1.0]);
        m.observe(&[1.0, 1.0, 1.0 + 1e-6]);
        assert!(m.converged());
    }

    #[test]
    fn detects_divergence() {
        let mut m = ConvergenceMonitor::new(2, 1e-3, 10.0);
        m.observe(&[1.0, 0.0]);
        assert!(!m.diverged(&[1.0, 0.0]));
        assert!(m.diverged(&[100.0, 0.0]));
        assert!(m.diverged(&[f64::NAN, 0.0]));
    }

    #[test]
    fn domain_violation_counts() {
        let obj = Objective::Logistic { lambda: 1.0 };
        let y = [1.0, 1.0, -1.0, -1.0];
        let alpha = [0.5, 1.5, -0.5, 0.5]; // 2nd (s=1.5) and 4th (s=-0.5) bad
        let v = ConvergenceMonitor::domain_violation(&obj, &alpha, &y);
        assert!((v - 0.5).abs() < 1e-12);
        let ridge = Objective::Ridge { lambda: 1.0 };
        assert_eq!(ConvergenceMonitor::domain_violation(&ridge, &alpha, &y), 0.0);
    }
}
