//! Fused update kernels over the shard-resident interleaved layout
//! ([`crate::data::shard`]).
//!
//! The `DataMatrix` hot path walks an example **twice per coordinate
//! step** through trait-dispatched calls: `dot_col` (margin) then
//! `axpy_col` (update), each streaming two split arrays (`idx` + `val`).
//! These kernels fuse the whole step into one call over **one**
//! interleaved entry slice: the margin pass streams the slice forward
//! once, the 1-D dual solve runs in registers (closed-form for
//! ridge/hinge, the safeguarded Newton fallback for logistic — see
//! [`Objective::delta`]), and the update pass re-walks the same slice
//! while it is still resident in L1. Combined with
//! [`Shard::prefetch_bucket`] on the *next* bucket of the shuffled
//! permutation, a coordinate step costs one cold streaming read instead
//! of four.
//!
//! ## Bit-wise determinism
//!
//! Every kernel reproduces the exact floating-point evaluation order of
//! the `DataMatrix` path it replaces:
//!
//! * [`dot_entries`] routes through the single shared 4-chain reduction
//!   [`crate::util::dot4_by`] — the same implementation behind
//!   [`crate::util::dot`] (dense columns) and `CscMatrix::dot_col_in`
//!   (sparse columns, whichever segment of the chunked matrix serves
//!   them), so the three are product-for-product identical **by
//!   construction**, not by textual convention;
//! * [`axpy_entries`] applies `v[i] += scale · x` element-wise in stream
//!   order, exactly like `axpy_col`;
//! * the wild kernels ([`dot_entries_atomic`], [`axpy_entries_wild`]) are
//!   sequential, matching `dot_col_atomic`/`axpy_col_wild`.
//!
//! Hence Interleaved and Csc layouts train **bit-wise identical**
//! `alpha`/`v` for every solver — locked in by
//! `rust/tests/pool_equivalence.rs`.

use crate::data::shard::{Entry, Shard};
use crate::glm::Objective;
use crate::util::atomic::{AtomicF64, PaddedAtomicF64};

/// `⟨x, v⟩` over an interleaved entry slice — the shared 4-chain
/// reduction ([`crate::util::dot4_by`]), so dense and sparse sources
/// agree bit-wise with their `dot_col` implementations by construction.
#[inline]
pub fn dot_entries(entries: &[Entry], v: &[f64]) -> f64 {
    crate::util::dot4_by(entries.len(), |k| {
        let e = &entries[k];
        (e.val(), v[e.idx as usize])
    })
}

/// `v += scale · x` over an interleaved entry slice (stream order, like
/// `axpy_col`). The slice is L1-hot here: the fused step just streamed it
/// for the margin.
#[inline]
pub fn axpy_entries(entries: &[Entry], scale: f64, v: &mut [f64]) {
    for e in entries {
        v[e.idx as usize] += scale * e.val();
    }
}

/// One bucket of fused coordinate steps against plain (`alpha`, `v`) —
/// the interleaved counterpart of [`crate::solver::seq::run_bucket`].
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn run_bucket(
    shard: &Shard,
    obj: &Objective,
    range: std::ops::Range<usize>,
    alpha: &mut [f64],
    v: &mut [f64],
    y: &[f64],
    norms: &[f64],
    inv_lambda_n: f64,
    n_eff: usize,
) {
    for j in range {
        let entries = shard.entries(j);
        let xw = dot_entries(entries, v) * inv_lambda_n;
        let delta = obj.delta(alpha[j], xw, norms[j], y[j], n_eff);
        if delta != 0.0 {
            alpha[j] += delta;
            axpy_entries(entries, delta, v);
        }
    }
}

/// One bucket of fused coordinate steps for the replica solvers: `alpha`
/// slots are atomic (disjoint per worker within an epoch) and the local
/// replica `u` absorbs the σ′-scaled update `u += σ′·δ·x` — the
/// interleaved counterpart of the `dom`/`numa` inner loops.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn run_bucket_replica(
    shard: &Shard,
    obj: &Objective,
    range: std::ops::Range<usize>,
    alpha: &[AtomicF64],
    u: &mut [f64],
    y: &[f64],
    norms: &[f64],
    inv_lambda_n: f64,
    n_eff: usize,
    sigma: f64,
) {
    for j in range {
        let entries = shard.entries(j);
        let a = alpha[j].load();
        let xw = dot_entries(entries, u) * inv_lambda_n;
        let delta = obj.delta(a, xw, norms[j], y[j], n_eff);
        if delta != 0.0 {
            alpha[j].store(a + delta);
            axpy_entries(entries, sigma * delta, u);
        }
    }
}

/// `⟨x, v⟩` against the wild solver's padded atomic shared vector —
/// sequential, matching `dot_col_atomic` on both source layouts.
#[inline]
pub fn dot_entries_atomic(entries: &[Entry], v: &[PaddedAtomicF64]) -> f64 {
    let mut s = 0.0;
    for e in entries {
        s += e.val() * v[e.idx as usize].load();
    }
    s
}

/// Unsynchronized `v += scale · x` (the wild `ADD`) over the interleaved
/// stream — concurrent callers may lose updates, exactly like
/// `axpy_col_wild`.
#[inline]
pub fn axpy_entries_wild(entries: &[Entry], scale: f64, v: &[PaddedAtomicF64]) {
    for e in entries {
        v[e.idx as usize].add_wild(scale * e.val());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shard::ShardedLayout;
    use crate::data::{CscMatrix, DataMatrix, DenseMatrix};
    use crate::solver::Buckets;
    use crate::util::atomic::padded_atomic_vec;

    fn sparse() -> CscMatrix {
        CscMatrix::from_examples(
            6,
            &[
                vec![(0, 1.5), (2, -2.0), (5, 0.25)],
                vec![(1, 3.0), (3, 1.0), (4, -0.5), (5, 2.0), (0, 0.125)],
            ],
        )
    }

    #[test]
    fn dot_entries_bitwise_matches_csc_dot_col() {
        let m = sparse();
        let layout = ShardedLayout::single(&m, &Buckets::new(m.n(), 1));
        let v: Vec<f64> = (0..6).map(|i| (i as f64) * 0.37 - 1.1).collect();
        for j in 0..m.n() {
            let a = m.dot_col(j, &v);
            let b = dot_entries(layout.shard(0).entries(j), &v);
            assert_eq!(a.to_bits(), b.to_bits(), "example {j}");
        }
    }

    #[test]
    fn dot_entries_bitwise_matches_dense_dot_col() {
        // 9 features exercises both the 4-chains and the sequential tail
        let col_a: Vec<f64> = (0..9).map(|i| (i as f64).sin() + 0.3).collect();
        let col_b: Vec<f64> = (0..9).map(|i| 1.0 / (i as f64 + 2.0)).collect();
        let m = DenseMatrix::from_columns(9, &[&col_a, &col_b]);
        let layout = ShardedLayout::single(&m, &Buckets::new(2, 1));
        let v: Vec<f64> = (0..9).map(|i| (i as f64) * 0.21 - 0.9).collect();
        for j in 0..2 {
            let a = m.dot_col(j, &v);
            let b = dot_entries(layout.shard(0).entries(j), &v);
            assert_eq!(a.to_bits(), b.to_bits(), "example {j}");
        }
    }

    #[test]
    fn axpy_entries_bitwise_matches_axpy_col() {
        let m = sparse();
        let layout = ShardedLayout::single(&m, &Buckets::new(m.n(), 2));
        for j in 0..m.n() {
            let mut a = vec![0.5f64; 6];
            let mut b = vec![0.5f64; 6];
            m.axpy_col(j, -1.75, &mut a);
            axpy_entries(layout.shard(0).entries(j), -1.75, &mut b);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn atomic_kernels_match_trait_path() {
        let m = sparse();
        let layout = ShardedLayout::single(&m, &Buckets::new(m.n(), 1));
        let va = padded_atomic_vec(6);
        let vb = padded_atomic_vec(6);
        for i in 0..6 {
            va[i].store(i as f64 * 0.4 - 1.0);
            vb[i].store(i as f64 * 0.4 - 1.0);
        }
        for j in 0..m.n() {
            let a = m.dot_col_atomic(j, &va);
            let b = dot_entries_atomic(layout.shard(0).entries(j), &vb);
            assert_eq!(a.to_bits(), b.to_bits());
            m.axpy_col_wild(j, 0.3, &va);
            axpy_entries_wild(layout.shard(0).entries(j), 0.3, &vb);
        }
        for i in 0..6 {
            assert_eq!(va[i].load().to_bits(), vb[i].load().to_bits());
        }
    }
}
