//! Worker execution strategy.
//!
//! The replica-based solvers (`dom`, `numa`) are *deterministic* given the
//! epoch assignments: workers only touch disjoint `α` coordinates and
//! private `v` replicas between merge points, and the caller reduces the
//! returned deltas in job order. That means running the worker closures on
//! real threads, on the persistent worker pool, or sequentially on one
//! core produces bit-wise identical models — which is how this repo
//! reproduces the paper's convergence results (epoch counts) for 8–32
//! "threads" on any host (see DESIGN.md §4 substitutions).
//!
//! Four interchangeable executors:
//!
//! * [`Executor::Pool`] — the production path: persistent NUMA-aware
//!   workers (see [`WorkerPool`]) created once per `train()` call, so the
//!   per-merge-round dispatch is a queue push instead of an OS thread
//!   spawn/join.
//! * [`Executor::Shared`] — the same resident pool, but owned by a
//!   longer-lived session ([`crate::serve::Session`], hyperparameter
//!   sweeps) and reused across many `train()` calls, amortizing the spawn
//!   across the whole session.
//! * [`Executor::Threads`] — spawn-per-batch via `std::thread::scope`;
//!   kept as the zero-state reference implementation the pool is tested
//!   against.
//! * [`Executor::Sequential`] — in order on the calling thread
//!   (virtual-thread mode; the basis of `crate::vthread`).
//!
//! The bit-wise equivalence across executors is asserted in
//! `rust/tests/solver_equivalence.rs` and `rust/tests/pool_equivalence.rs`;
//! `rust/tests/serving.rs` extends it to the shared-pool serving path.

use crate::solver::pool::WorkerPool;
use crate::sysinfo::Topology;
use std::sync::Arc;

/// How to run a batch of independent worker jobs.
pub enum Executor {
    /// One OS thread per job per batch (`std::thread::scope`).
    Threads,
    /// Run jobs in order on the calling thread (virtual-thread mode).
    Sequential,
    /// Dispatch onto a run-scoped resident [`WorkerPool`].
    Pool(WorkerPool),
    /// Dispatch onto a pool owned by someone else (a serving
    /// [`Session`](crate::serve::Session)) and shared across many runs —
    /// the workers outlive this executor and this training run.
    Shared(Arc<WorkerPool>),
}

impl std::fmt::Debug for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Executor::Threads => write!(f, "Threads"),
            Executor::Sequential => write!(f, "Sequential"),
            Executor::Pool(p) => write!(f, "Pool({} workers)", p.workers()),
            Executor::Shared(p) => write!(f, "Shared({} workers)", p.workers()),
        }
    }
}

impl Executor {
    /// Run all jobs to completion, returning their results in job order.
    pub fn run<R, F>(&self, jobs: Vec<F>) -> Vec<R>
    where
        R: Send,
        F: FnOnce() -> R + Send,
    {
        match self {
            Executor::Sequential => jobs.into_iter().map(|f| f()).collect(),
            Executor::Threads => std::thread::scope(|s| {
                let handles: Vec<_> = jobs.into_iter().map(|f| s.spawn(f)).collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("worker panicked"))
                    .collect()
            }),
            Executor::Pool(pool) => pool.run(jobs),
            Executor::Shared(pool) => pool.run(jobs),
        }
    }

    /// Run NUMA-node-tagged jobs. `Pool` routes every job to a worker
    /// resident on the tagged node (the hierarchical solver's per-node
    /// bucket queues); `Threads` and `Sequential` ignore the tags. All
    /// executors return results in job order, so the tag is a placement
    /// hint only and never affects the trained model.
    pub fn run_tagged<R, F>(&self, jobs: Vec<(usize, F)>) -> Vec<R>
    where
        R: Send,
        F: FnOnce() -> R + Send,
    {
        match self {
            Executor::Pool(pool) => pool.run_tagged(jobs),
            Executor::Shared(pool) => pool.run_tagged(jobs),
            other => other.run(jobs.into_iter().map(|(_, f)| f).collect()),
        }
    }

    /// Per-worker busy/job statistics when a resident pool backs this
    /// executor; `None` for `Threads`/`Sequential` (no persistent workers
    /// to account). Feeds the imbalance column of the per-epoch
    /// [`ConvergenceTrace`](crate::obs::ConvergenceTrace).
    pub fn stats(&self) -> Option<crate::solver::pool::PoolStats> {
        match self {
            Executor::Pool(pool) => Some(pool.stats()),
            Executor::Shared(pool) => Some(pool.stats()),
            Executor::Threads | Executor::Sequential => None,
        }
    }
}

/// Which executor a `train()` call should build — the config knob carried
/// by [`SolverConfig`](crate::solver::SolverConfig). Resolved into a
/// concrete [`Executor`] (spawning the pool's resident workers for
/// [`ExecPolicy::Pool`]) exactly once per training run.
#[derive(Clone)]
pub enum ExecPolicy {
    /// Persistent NUMA-aware worker pool, created for this run (default).
    Pool,
    /// Fresh OS threads per merge round (the pre-pool behaviour).
    Threads,
    /// Single-core in-order execution (deterministic vthread mode).
    Sequential,
    /// Reuse a caller-owned resident pool across `train()` calls — the
    /// session-scoped handle the serving subsystem (`crate::serve`) and
    /// hyperparameter sweeps use to amortize worker spawn. Worker-count
    /// mismatch story: if the shared pool's worker count differs from the
    /// run's `threads`, a run-scoped pool is rebuilt instead (and the
    /// mismatch is logged) — the shared pool is never resized under its
    /// owner.
    Shared(Arc<WorkerPool>),
}

impl std::fmt::Debug for ExecPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecPolicy::Pool => write!(f, "Pool"),
            ExecPolicy::Threads => write!(f, "Threads"),
            ExecPolicy::Sequential => write!(f, "Sequential"),
            ExecPolicy::Shared(p) => write!(f, "Shared({} workers)", p.workers()),
        }
    }
}

impl PartialEq for ExecPolicy {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (ExecPolicy::Pool, ExecPolicy::Pool)
            | (ExecPolicy::Threads, ExecPolicy::Threads)
            | (ExecPolicy::Sequential, ExecPolicy::Sequential) => true,
            (ExecPolicy::Shared(a), ExecPolicy::Shared(b)) => Arc::ptr_eq(a, b),
            _ => false,
        }
    }
}

impl ExecPolicy {
    /// Build the executor for a run of `threads` workers on `topo`.
    pub fn build(&self, threads: usize, topo: &Topology) -> Executor {
        match self {
            ExecPolicy::Sequential => Executor::Sequential,
            ExecPolicy::Threads => Executor::Threads,
            ExecPolicy::Pool => Executor::Pool(WorkerPool::new(threads, topo)),
            ExecPolicy::Shared(pool) => {
                if pool.workers() == threads {
                    Executor::Shared(Arc::clone(pool))
                } else {
                    crate::diag!(
                        Warn,
                        "parlin: shared pool has {} workers but the run wants {threads}; \
                         building a run-scoped pool (rebuild-on-mismatch)",
                        pool.workers()
                    );
                    Executor::Pool(WorkerPool::new(threads, topo))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn executors() -> Vec<Executor> {
        vec![
            Executor::Sequential,
            Executor::Threads,
            Executor::Pool(WorkerPool::new(4, &Topology::flat(4))),
            Executor::Shared(Arc::new(WorkerPool::new(4, &Topology::flat(4)))),
        ]
    }

    #[test]
    fn all_executors_preserve_order() {
        for exec in executors() {
            let jobs: Vec<_> = (0..8).map(|i| move || i * 10).collect();
            assert_eq!(exec.run(jobs), vec![0, 10, 20, 30, 40, 50, 60, 70]);
        }
    }

    #[test]
    fn tagged_run_preserves_order_everywhere() {
        for exec in executors() {
            let jobs: Vec<(usize, _)> = (0..6usize).map(|i| (i % 2, move || i as u64)).collect();
            assert_eq!(exec.run_tagged(jobs), vec![0, 1, 2, 3, 4, 5]);
        }
    }

    #[test]
    fn threads_actually_run_concurrent_jobs() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        for exec in [
            Executor::Threads,
            Executor::Pool(WorkerPool::new(4, &Topology::flat(4))),
        ] {
            let counter = AtomicUsize::new(0);
            let jobs: Vec<_> = (0..4)
                .map(|_| {
                    let c = &counter;
                    move || c.fetch_add(1, Ordering::SeqCst)
                })
                .collect();
            let mut got = exec.run(jobs);
            got.sort_unstable();
            assert_eq!(got, vec![0, 1, 2, 3]);
        }
    }

    #[test]
    fn stats_available_exactly_for_pool_backed_executors() {
        for exec in executors() {
            let jobs: Vec<_> = (0..4).map(|i| move || i * 2).collect();
            let _ = exec.run(jobs);
            match &exec {
                Executor::Pool(_) | Executor::Shared(_) => {
                    let stats = exec.stats().expect("pool executors report stats");
                    assert_eq!(stats.per_worker.len(), 4);
                    assert!(stats.total_jobs() >= 4);
                }
                Executor::Threads | Executor::Sequential => assert!(exec.stats().is_none()),
            }
        }
    }

    #[test]
    fn policy_builds_matching_executor() {
        let topo = Topology::uniform(2, 2);
        assert!(matches!(
            ExecPolicy::Sequential.build(4, &topo),
            Executor::Sequential
        ));
        assert!(matches!(ExecPolicy::Threads.build(4, &topo), Executor::Threads));
        match ExecPolicy::Pool.build(4, &topo) {
            Executor::Pool(p) => assert_eq!(p.workers(), 4),
            other => panic!("expected pool, got {other:?}"),
        }
    }

    #[test]
    fn shared_policy_reuses_matching_pool() {
        let topo = Topology::flat(4);
        let pool = Arc::new(WorkerPool::new(4, &topo));
        match ExecPolicy::Shared(Arc::clone(&pool)).build(4, &topo) {
            Executor::Shared(p) => assert!(Arc::ptr_eq(&p, &pool), "must reuse the same pool"),
            other => panic!("expected shared pool, got {other:?}"),
        }
    }

    #[test]
    fn shared_policy_rebuilds_on_worker_count_mismatch() {
        let topo = Topology::flat(4);
        let pool = Arc::new(WorkerPool::new(4, &topo));
        match ExecPolicy::Shared(pool).build(2, &topo) {
            Executor::Pool(p) => assert_eq!(p.workers(), 2, "rebuilt pool must match the run"),
            other => panic!("expected a run-scoped rebuild, got {other:?}"),
        }
    }

    #[test]
    fn rebuild_on_mismatch_warns_through_diag() {
        use crate::obs::diag::{DiagCapture, Level};
        let cap = DiagCapture::start();
        let topo = Topology::flat(4);
        let pool = Arc::new(WorkerPool::new(4, &topo));
        let _ = ExecPolicy::Shared(pool).build(2, &topo);
        let recs = cap.take();
        let hit = recs
            .iter()
            .any(|r| r.level == Level::Warn && r.message.contains("rebuild-on-mismatch"));
        assert!(hit, "expected a Warn diag about the pool rebuild, got {recs:?}");
    }

    #[test]
    fn shared_policy_equality_is_pool_identity() {
        let topo = Topology::flat(2);
        let a = Arc::new(WorkerPool::new(2, &topo));
        let b = Arc::new(WorkerPool::new(2, &topo));
        assert_eq!(ExecPolicy::Shared(Arc::clone(&a)), ExecPolicy::Shared(Arc::clone(&a)));
        assert_ne!(ExecPolicy::Shared(a), ExecPolicy::Shared(b));
        assert_eq!(ExecPolicy::Pool, ExecPolicy::Pool);
        assert_ne!(ExecPolicy::Pool, ExecPolicy::Threads);
    }
}
