//! Worker execution strategy.
//!
//! The replica-based solvers (`dom`, `numa`) are *deterministic* given the
//! epoch assignments: workers only touch disjoint `α` coordinates and
//! private `v` replicas between merge points. That means running the worker
//! closures on real threads or sequentially on one core produces bit-wise
//! identical models — which is how this repo reproduces the paper's
//! convergence results (epoch counts) for 8–32 "threads" on any host (see
//! DESIGN.md §4 substitutions). `Threads` is the production path; the
//! equivalence is asserted in `rust/tests/solver_equivalence.rs`.

/// How to run a batch of independent worker jobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Executor {
    /// One OS thread per job (`std::thread::scope`).
    Threads,
    /// Run jobs in order on the calling thread (virtual-thread mode).
    Sequential,
}

impl Executor {
    /// Run all jobs to completion, returning their results in job order.
    pub fn run<R, F>(&self, jobs: Vec<F>) -> Vec<R>
    where
        R: Send,
        F: FnOnce() -> R + Send,
    {
        match self {
            Executor::Sequential => jobs.into_iter().map(|f| f()).collect(),
            Executor::Threads => std::thread::scope(|s| {
                let handles: Vec<_> = jobs.into_iter().map(|f| s.spawn(f)).collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("worker panicked"))
                    .collect()
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn both_executors_preserve_order() {
        for exec in [Executor::Sequential, Executor::Threads] {
            let jobs: Vec<_> = (0..8).map(|i| move || i * 10).collect();
            assert_eq!(exec.run(jobs), vec![0, 10, 20, 30, 40, 50, 60, 70]);
        }
    }

    #[test]
    fn threads_actually_run_concurrent_jobs() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let counter = AtomicUsize::new(0);
        let jobs: Vec<_> = (0..4)
            .map(|_| {
                let c = &counter;
                move || c.fetch_add(1, Ordering::SeqCst)
            })
            .collect();
        let mut got = Executor::Threads.run(jobs);
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3]);
    }
}
