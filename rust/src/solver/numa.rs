//! NUMA-hierarchical trainer (§3, "Numa-level optimizations").
//!
//! The paper treats each NUMA node as a distributed worker:
//!
//! * the requested threads are placed on the *minimum* number of nodes
//!   whose physical cores can hold them, always including the node where
//!   the dataset lives ([`Topology::place_threads`]);
//! * (buckets of) examples are **statically** partitioned across nodes —
//!   like CoCoA across machines — so a node only ever touches its own
//!   model coordinates (`α` is node-local);
//! * inside every node the paper's **dynamic** re-partitioning runs among
//!   that node's threads each epoch;
//! * each node keeps a private replica of the shared vector, intra-node
//!   merged every round, and the node replicas are reduced into the global
//!   `v` once per epoch (the cross-node allreduce the cost model charges
//!   at `t_reduce`).
//!
//! The training dataset itself is never replicated: it is read-only and
//! causes no coherence traffic (§3).

use crate::data::shard::RunLayout;
use crate::data::{DataMatrix, Dataset, LayoutPolicy, ShardedLayout};
use crate::glm::ModelState;
use crate::metrics::{EpochStats, RunRecord};
use crate::obs::{self, EventKind};
use crate::solver::exec::Executor;
use crate::solver::partition::Partitioner;
use crate::solver::seq::sdca_delta_at;
use crate::solver::tune::{EpochTuner, Knob, TuneCaps};
use crate::solver::{kernel, Buckets, ConvergenceMonitor, SolverConfig, TrainOutput};
use crate::sysinfo::Topology;
use crate::util::atomic::{atomic_vec, snapshot, AtomicF64};
use crate::util::{Rng, Timer};

/// Production entry point: workers come from the configured
/// [`ExecPolicy`](crate::solver::ExecPolicy) — by default a persistent
/// worker pool laid out on `topo`, created once here; its per-node bucket
/// queues then receive every node's merge-round jobs via
/// [`Executor::run_tagged`].
pub fn train_numa<M: DataMatrix>(
    ds: &Dataset<M>,
    cfg: &SolverConfig,
    topo: &Topology,
) -> TrainOutput {
    let exec = cfg.build_executor(topo);
    train_numa_exec(ds, cfg, topo, &exec)
}

/// Static split of the bucket space across active nodes, proportional to
/// each node's thread share (a node with more threads gets more buckets).
/// Public because a serving [`Session`](crate::serve::Session) computes
/// the same split to key its cached per-node layout
/// ([`ShardedLayout::matches_nodes`]).
pub fn node_bucket_ranges(num_buckets: usize, placement: &[usize]) -> Vec<std::ops::Range<u32>> {
    let total_threads: usize = placement.iter().sum();
    let mut ranges = Vec::with_capacity(placement.len());
    let mut next = 0usize;
    let mut assigned = 0usize;
    let active: usize = placement.iter().filter(|&&p| p > 0).count();
    let mut seen_active = 0usize;
    for &p in placement {
        if p == 0 {
            ranges.push(next as u32..next as u32);
            continue;
        }
        seen_active += 1;
        let share = if seen_active == active {
            num_buckets - assigned // last active node takes the remainder
        } else {
            num_buckets * p / total_threads
        };
        ranges.push(next as u32..(next + share) as u32);
        next += share;
        assigned += share;
    }
    ranges
}

pub fn train_numa_exec<M: DataMatrix>(
    ds: &Dataset<M>,
    cfg: &SolverConfig,
    topo: &Topology,
    exec: &Executor,
) -> TrainOutput {
    let n = ds.n();
    let obj = cfg.obj;
    let threads = cfg.threads.max(1);
    let placement = topo.place_threads(threads);
    let inv_lambda_n = 1.0 / (obj.lambda() * n as f64);
    // flat CoCoA+ σ′ across the hierarchy (safe ceiling: K = all workers);
    // Adaptive backtracks on the merged dual exactly like solver::dom
    let sigma_max = threads as f64;
    let mut sigma = match cfg.sigma {
        crate::solver::SigmaPolicy::Safe => sigma_max,
        crate::solver::SigmaPolicy::Adaptive => (sigma_max / 4.0).max(1.0),
        crate::solver::SigmaPolicy::Fixed(s) => s.max(1.0),
    };
    let adaptive = matches!(cfg.sigma, crate::solver::SigmaPolicy::Adaptive);
    // ratcheting relaxation floor — see solver::dom
    let mut sigma_floor = 1.0f64;

    let bucket_size = cfg.bucket.resolve_host(n);
    let buckets = Buckets::new(n, bucket_size);
    let node_ranges = node_bucket_ranges(buckets.count(), &placement);
    // Shard-resident interleaved layout: one shard per node, following the
    // *static* cross-node bucket split, so every node's workers stream
    // only entries their node materialized (first-touch keeps the shard on
    // the node's memory). Intra-node dynamic re-deals are index swaps.
    // A caller-provided cache (a serving session's resident per-node
    // layout) is reused when it describes exactly this dataset, bucket
    // geometry and node split — refits then skip the O(nnz) re-encode.
    let mut use_interleaved = cfg.layout == LayoutPolicy::Interleaved;
    let mut layout = RunLayout::resolve(
        use_interleaved,
        cfg.layout_cache.as_ref(),
        |l| l.matches_nodes(n, ds.d(), ds.x.nnz(), bucket_size, &node_ranges),
        || ShardedLayout::for_nodes(&ds.x, &buckets, &node_ranges),
    );

    // per-node dynamic partitioners over the node's own bucket range
    let mut node_parts: Vec<Option<Partitioner>> = placement
        .iter()
        .zip(&node_ranges)
        .map(|(&p, r)| {
            (p > 0).then(|| Partitioner::new(cfg.partition, (r.end - r.start) as usize, p))
        })
        .collect();

    let init = crate::solver::initial_state(cfg, ds);
    let alpha: Vec<AtomicF64> = atomic_vec(n);
    for (slot, &a) in alpha.iter().zip(init.alpha.iter()) {
        if a != 0.0 {
            slot.store(a);
        }
    }
    let mut mon = ConvergenceMonitor::new(n, cfg.tol, cfg.divergence_factor);
    if cfg.warm_start.is_some() {
        mon.seed(&init.alpha);
    }
    let mut v_global = init.v;
    // per-node replicas of the shared vector
    let mut v_nodes: Vec<Vec<f64>> = placement
        .iter()
        .map(|&p| if p > 0 { v_global.clone() } else { Vec::new() })
        .collect();
    let mut rng = Rng::new(cfg.seed);
    // The paper's hierarchy synchronizes replicas at epoch granularity:
    // "Each node holds its own replica of the shared vector, which is
    // reduced across nodes at the end of each epoch" (§3). Intra-epoch
    // merges interact badly with the flat σ′ scaling (the per-round
    // replica reset discards the σ′-amplified self-view that lets a local
    // pass make coordinated progress), so the hierarchical solver pins
    // one round per epoch; `merges_per_epoch` applies to `dom` only.
    let rounds = 1usize;

    let total = Timer::start();
    let mut epochs = Vec::new();
    let mut converged = false;
    // D(0) = 0 at a cold start; warm starts resume from their own dual
    let mut prev_dual = if adaptive && cfg.warm_start.is_some() {
        let st = ModelState {
            alpha: snapshot(&alpha),
            v: v_global.clone(),
        };
        crate::glm::gap::dual_value(ds, &obj, &st)
    } else {
        0.0f64
    };
    let active = placement.iter().filter(|&&p| p > 0).count();
    let label = format!("numa({active}n,bucket={bucket_size})");
    // per-epoch convergence telemetry: reuses rel/gap/wall_s below, adds
    // no clock read or gap computation of its own
    let mut conv = obs::ConvergenceTrace::new(label.clone(), threads);
    // The hierarchical solver pins its bucketing (the static cross-node
    // split is keyed to it) and its per-node thread placement, so the
    // tuner may only move the bit-free layout knob.
    let caps = TuneCaps { bucket: false, layout: true, workers: false };
    let mut tuner = EpochTuner::for_run(
        cfg.tune,
        caps,
        &label,
        bucket_size,
        use_interleaved,
        threads,
        cfg.partition == crate::solver::Partitioning::Dynamic,
    );
    let epoch_ctr = obs::registry().counter("solver.epochs");
    let epoch_wall_us = obs::registry().histogram("solver.epoch_wall_us");
    for epoch in 1..=cfg.max_epochs {
        let t = Timer::start();
        obs::emit(EventKind::EpochBegin, obs::CLASS_NONE, 0, epoch as u64);
        // armed fault plans fire here (coordinator thread, before any
        // dispatch) so an injected panic unwinds cleanly through the epoch
        crate::fault::poke(crate::fault::FaultSite::Epoch);
        // cooperative cancellation: the once-per-epoch checkpoint
        if let Some(c) = &cfg.cancel {
            c.checkpoint(&label, epoch);
        }
        let snap_state = adaptive.then(|| (snapshot(&alpha), v_global.clone()));
        let n_eff = ((n as f64 / sigma).round() as usize).max(1);
        // per-node epoch assignments (bucket ids relative to node range)
        let assignments: Vec<Option<crate::solver::partition::EpochAssignment>> = node_parts
            .iter_mut()
            .map(|p| p.as_mut().map(|p| p.assign(&mut rng)))
            .collect();
        for round in 0..rounds {
            // run every (node, thread) worker; workers read their node's
            // replica and return the replica delta. Jobs are tagged with
            // their node so the pool executor queues each one on a worker
            // resident on that node (per-node bucket queues); the tag is
            // ignored by the other executors and never affects results.
            let mut jobs = Vec::new();
            let mut job_node = Vec::new();
            for (k, asg) in assignments.iter().enumerate() {
                let Some(asg) = asg else { continue };
                let range_lo = node_ranges[k].start;
                for tl in &asg.per_worker {
                    let seg = super::dom::segment(tl, round, rounds);
                    let (ds, obj, buckets, alpha, v_ref) =
                        (&*ds, &obj, &buckets, &alpha[..], &v_nodes[k][..]);
                    let shard = if use_interleaved { layout.shard(k) } else { None };
                    jobs.push((k, move || {
                        // σ′-scaled replica: u = v_node + σ′·A·Δα_local
                        // (see solver::dom::worker_round for the algebra)
                        let mut u = v_ref.to_vec();
                        if let Some(sh) = shard {
                            for (i, &b) in seg.iter().enumerate() {
                                if let Some(&nb) = seg.get(i + 1) {
                                    sh.prefetch_bucket((range_lo + nb) as usize);
                                }
                                kernel::run_bucket_replica(
                                    sh,
                                    obj,
                                    buckets.range((range_lo + b) as usize),
                                    alpha,
                                    &mut u,
                                    &ds.y,
                                    ds.norms(),
                                    inv_lambda_n,
                                    n_eff,
                                    sigma,
                                );
                            }
                        } else {
                            // source-matrix walk through a per-worker
                            // cursor (amortized segment lookup)
                            let mut cur = ds.x.col_cursor();
                            for &b in seg {
                                let global_b = (range_lo + b) as usize;
                                for j in buckets.range(global_b) {
                                    let a = alpha[j].load();
                                    let delta = sdca_delta_at(
                                        &mut cur, ds, obj, j, a, &u, inv_lambda_n, n_eff,
                                    );
                                    if delta != 0.0 {
                                        alpha[j].store(a + delta);
                                        cur.axpy(j, sigma * delta, &mut u);
                                    }
                                }
                            }
                        }
                        for (l, g) in u.iter_mut().zip(v_ref.iter()) {
                            *l = (*l - g) / sigma;
                        }
                        u
                    }));
                    job_node.push(k);
                }
            }
            let deltas = exec.run_tagged(jobs);
            // intra-node merge: each node's replica absorbs its own
            // threads' deltas (cross-node reduce happens once per epoch)
            for (dv, &k) in deltas.iter().zip(&job_node) {
                crate::util::axpy(1.0, dv, &mut v_nodes[k]);
            }
        }
        // cross-node allreduce: v_global += Σ_k (v_nodes[k] − v_global);
        // then every node refreshes its replica from the reduced vector.
        let mut merged = v_global.clone();
        for (k, vn) in v_nodes.iter().enumerate() {
            if placement[k] == 0 {
                continue;
            }
            for (m, (nv, g)) in merged.iter_mut().zip(vn.iter().zip(v_global.iter())) {
                *m += nv - g;
            }
        }
        v_global = merged;
        let mut reverted = false;
        if adaptive {
            let st = ModelState {
                alpha: snapshot(&alpha),
                v: v_global.clone(),
            };
            let dual = crate::glm::gap::dual_value(ds, &obj, &st);
            if dual + 1e-12 * dual.abs().max(1.0) < prev_dual && sigma < sigma_max {
                let (a_snap, v_snap) = snap_state.unwrap();
                for (slot, val) in alpha.iter().zip(&a_snap) {
                    slot.store(*val);
                }
                v_global = v_snap;
                sigma_floor = (sigma * 2.0).min(sigma_max);
                sigma = sigma_floor;
                reverted = true;
            } else {
                prev_dual = dual;
                sigma = (sigma / 1.15).max(sigma_floor);
            }
        }
        for (k, vn) in v_nodes.iter_mut().enumerate() {
            if placement[k] > 0 {
                vn.copy_from_slice(&v_global);
            }
        }

        let a_snap = snapshot(&alpha);
        // reverted epochs made no accepted progress: skip the
        // convergence check (see solver::dom)
        let rel = if reverted {
            f64::INFINITY
        } else {
            mon.observe(&a_snap)
        };
        let gap = if cfg.gap_tol.is_some() && epoch % cfg.gap_check_every == 0 {
            let st = ModelState {
                alpha: a_snap.clone(),
                v: v_global.clone(),
            };
            Some(crate::glm::duality_gap(ds, &obj, &st).gap)
        } else {
            None
        };
        let wall_s = t.elapsed_s();
        epochs.push(EpochStats {
            epoch,
            wall_s,
            rel_change: rel,
            gap,
            primal: None,
        });
        let pool_stats = exec.stats();
        conv.record(
            epoch,
            wall_s,
            rel,
            gap,
            pool_stats.as_ref().map(|s| s.imbalance()),
            pool_stats.as_ref().map(|s| s.total_busy_s()),
        );
        // Epoch-boundary tuning: layout is the only knob numa exposes.
        for d in tuner.observe(conv.points.last().expect("recorded this epoch")) {
            if d.knob == Knob::Layout {
                use_interleaved = d.to == "interleaved";
                if use_interleaved && layout.shard(0).is_none() {
                    layout = RunLayout::resolve(true, None, |_| false, || {
                        ShardedLayout::for_nodes(&ds.x, &buckets, &node_ranges)
                    });
                }
            }
        }
        epoch_ctr.inc();
        epoch_wall_us.record((wall_s * 1e6) as u64);
        obs::emit(EventKind::EpochEnd, obs::CLASS_NONE, 0, epoch as u64);
        if mon.converged() || gap.map(|g| g < cfg.gap_tol.unwrap()).unwrap_or(false) {
            converged = true;
            break;
        }
    }

    let st = ModelState {
        alpha: snapshot(&alpha),
        v: v_global,
    };
    let record = RunRecord {
        solver: label,
        threads,
        epochs,
        converged,
        diverged: false,
        total_wall_s: total.elapsed_s(),
    };
    TrainOutput::assemble(ds, &obj, st, record)
        .with_convergence(conv)
        .with_tune_log(tuner.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::glm::Objective;
    use crate::data::synthetic;
    use crate::solver::Variant;

    fn cfg(lambda: f64, threads: usize) -> SolverConfig {
        SolverConfig::new(Objective::Logistic { lambda })
            .with_variant(Variant::Numa)
            .with_threads(threads)
            .with_tol(1e-5)
            .with_max_epochs(600)
    }

    #[test]
    fn node_ranges_partition() {
        let r = node_bucket_ranges(100, &[4, 4, 0, 2]);
        assert_eq!(r[0], 0..40);
        assert_eq!(r[1], 40..80);
        assert_eq!(r[2].len(), 0);
        assert_eq!(r[3], 80..100);
    }

    #[test]
    fn converges_across_nodes() {
        let ds = synthetic::dense_classification(600, 20, 1);
        let topo = Topology::uniform(4, 2);
        let out = train_numa(&ds, &cfg(1.0 / 600.0, 8), &topo);
        assert!(out.converged, "epochs={}", out.epochs_run);
        assert!(out.final_gap < 1e-3, "gap={}", out.final_gap);
    }

    #[test]
    fn single_node_matches_domesticated_policy() {
        // 2 threads on a 1-node topology: still correct
        let ds = synthetic::sparse_classification(400, 100, 0.05, 2);
        let topo = Topology::flat(4);
        let out = train_numa(&ds, &cfg(1.0 / 400.0, 2), &topo);
        assert!(out.converged);
        assert!(out.final_gap < 1e-2);
    }

    #[test]
    fn executors_identical() {
        let ds = synthetic::dense_classification(300, 10, 3);
        let topo = Topology::uniform(2, 2);
        let c = cfg(1e-3, 4).with_max_epochs(15).with_tol(0.0);
        let a = train_numa_exec(&ds, &c, &topo, &Executor::Threads);
        let b = train_numa_exec(&ds, &c, &topo, &Executor::Sequential);
        assert_eq!(a.state.alpha, b.state.alpha);
        assert_eq!(a.state.v, b.state.v);
    }

    #[test]
    fn node_layout_cache_reuse_is_bitwise_identical() {
        let ds = synthetic::sparse_classification(300, 60, 0.08, 6);
        let topo = Topology::uniform(2, 2);
        let c = cfg(1.0 / 300.0, 4)
            .with_bucket(crate::solver::BucketPolicy::Fixed(4))
            .with_max_epochs(25)
            .with_tol(0.0);
        let fresh = train_numa(&ds, &c, &topo);
        // pre-build the exact per-node layout a session would keep resident
        let buckets = Buckets::new(ds.n(), 4);
        let ranges = node_bucket_ranges(buckets.count(), &topo.place_threads(4));
        let cache = std::sync::Arc::new(ShardedLayout::for_nodes(&ds.x, &buckets, &ranges));
        assert!(cache.matches_nodes(ds.n(), ds.d(), ds.x.nnz(), 4, &ranges));
        let cached = train_numa(&ds, &c.clone().with_layout_cache(cache), &topo);
        assert_eq!(fresh.state.alpha, cached.state.alpha);
        assert_eq!(fresh.state.v, cached.state.v);
        // a single-shard cache (the predict-side layout) must be ignored,
        // not streamed against the wrong node split
        let single = std::sync::Arc::new(ShardedLayout::single(&ds.x, &buckets));
        let ignored = train_numa(&ds, &c.clone().with_layout_cache(single), &topo);
        assert_eq!(fresh.state.alpha, ignored.state.alpha);
        assert_eq!(fresh.state.v, ignored.state.v);
    }

    #[test]
    fn v_consistency() {
        let ds = synthetic::dense_classification(250, 8, 4);
        let topo = Topology::uniform(2, 3);
        let out = train_numa(&ds, &cfg(0.01, 6), &topo);
        assert!(out.state.v_drift(&ds) < 1e-8);
    }

    #[test]
    fn same_solution_as_sequential() {
        let ds = synthetic::dense_classification(500, 15, 5);
        let obj = Objective::Logistic { lambda: 1e-3 };
        let topo = Topology::uniform(4, 2);
        let seq = crate::solver::seq::train_sequential(
            &ds,
            &SolverConfig::new(obj).with_tol(1e-7).with_max_epochs(1000),
        );
        let numa = train_numa(&ds, &cfg(1e-3, 8).with_tol(1e-7).with_max_epochs(1500), &topo);
        let dist = crate::util::rel_change(&seq.weights(&obj), &numa.weights(&obj));
        assert!(dist < 5e-3, "solutions differ: {dist}");
    }
}
