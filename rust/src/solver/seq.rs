//! Sequential SDCA with the bucket optimization — the paper's §3
//! single-threaded trainer and the building block every parallel variant
//! reuses for its per-worker inner loop.

use crate::data::shard::RunLayout;
use crate::data::{ColCursor, DataMatrix, Dataset, LayoutPolicy, ShardedLayout};
use crate::glm::Objective;
use crate::metrics::{EpochStats, RunRecord};
use crate::obs::{self, EventKind};
use crate::solver::tune::{EpochTuner, Knob, TuneCaps};
use crate::solver::{kernel, BucketPolicy, Buckets, ConvergenceMonitor, SolverConfig, TrainOutput};
use crate::util::{Rng, Timer};

/// One exact SDCA coordinate step on example `j` against the vector `v`
/// (shared, replica or node-local — the caller decides), read through a
/// column cursor — the loop form every solver's source-matrix
/// (`--layout csc`) inner loop uses: the cursor amortizes the segment
/// lookup of the chunked dataset across consecutive steps.
///
/// `n_eff` is the example count used for the curvature of the local
/// subproblem: the global `n` for the sequential/wild solvers, and the
/// CoCoA-safe `n/K` for `K`-way replica solvers (σ′ = K scaling).
/// Returns `δ`; the caller owns applying `α_j += δ` and `v += δ·x_j`.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn sdca_delta_at<M: DataMatrix>(
    cur: &mut ColCursor<'_, M>,
    ds: &Dataset<M>,
    obj: &Objective,
    j: usize,
    alpha_j: f64,
    v: &[f64],
    inv_lambda_n: f64,
    n_eff: usize,
) -> f64 {
    let xw = cur.dot(j, v) * inv_lambda_n;
    obj.delta(alpha_j, xw, ds.norm_sq(j), ds.y[j], n_eff)
}

/// Run one bucket of consecutive coordinates in-place against (`alpha`,
/// `v`). Shared by the sequential, domesticated and NUMA inner loops.
/// Column access goes through a [`ColCursor`], so a bucket that sits
/// inside one dataset segment (the overwhelmingly common case — buckets
/// are small, segments are append batches) pays exactly one seat.
#[inline]
pub fn run_bucket<M: DataMatrix>(
    ds: &Dataset<M>,
    obj: &Objective,
    range: std::ops::Range<usize>,
    alpha: &mut [f64],
    v: &mut [f64],
    inv_lambda_n: f64,
    n_eff: usize,
) {
    let mut cur = ds.x.col_cursor();
    for j in range {
        let delta = sdca_delta_at(&mut cur, ds, obj, j, alpha[j], v, inv_lambda_n, n_eff);
        if delta != 0.0 {
            alpha[j] += delta;
            cur.axpy(j, delta, v);
        }
    }
}

/// §3 single-threaded trainer: shuffled bucket order, exact coordinate
/// steps, convergence on relative model change (+ optional gap check).
pub fn train_sequential<M: DataMatrix>(ds: &Dataset<M>, cfg: &SolverConfig) -> TrainOutput {
    let n = ds.n();
    let obj = cfg.obj;
    let mut bucket_size = cfg.bucket.resolve_host(n);
    let mut buckets = Buckets::new(n, bucket_size);
    // Interleaved layout: one global shard, materialized once for the
    // whole run (or borrowed from the caller's cache when its geometry
    // matches) — per-epoch shuffles only permute bucket *ids* over it.
    // `use_interleaved` can flip at an epoch boundary under the tuner;
    // both encodings route through `util::dot4_by`, so the switch is
    // bit-free (locked by `rust/tests/tune.rs`).
    let mut use_interleaved = cfg.layout == LayoutPolicy::Interleaved;
    let mut layout = RunLayout::resolve(
        use_interleaved,
        cfg.layout_cache.as_ref(),
        |l| l.matches_single(n, ds.d(), ds.x.nnz(), bucket_size),
        || ShardedLayout::single(&ds.x, &buckets),
    );
    let mut ids = buckets.ids();
    let mut rng = Rng::new(cfg.seed);
    let mut st = crate::solver::initial_state(cfg, ds);
    let mut mon = ConvergenceMonitor::new(n, cfg.tol, cfg.divergence_factor);
    if cfg.warm_start.is_some() {
        // measure the first epoch's progress against the warm state, so a
        // refit that is already converged can stop after one epoch
        mon.seed(&st.alpha);
    }
    let inv_lambda_n = 1.0 / (obj.lambda() * n as f64);

    let total = Timer::start();
    let mut epochs = Vec::new();
    let mut converged = false;
    let label = format!("seq(bucket={bucket_size})");
    // per-epoch convergence telemetry: reuses rel/gap/wall_s below, adds
    // no clock read or gap computation of its own (no pool → no imbalance)
    let mut conv = obs::ConvergenceTrace::new(label.clone(), 1);
    let caps = TuneCaps {
        bucket: matches!(cfg.bucket, BucketPolicy::Auto),
        layout: true,
        workers: false,
    };
    let mut tuner =
        EpochTuner::for_run(cfg.tune, caps, &label, bucket_size, use_interleaved, 1, false);
    let epoch_ctr = obs::registry().counter("solver.epochs");
    let epoch_wall_us = obs::registry().histogram("solver.epoch_wall_us");
    for epoch in 1..=cfg.max_epochs {
        let t = Timer::start();
        obs::emit(EventKind::EpochBegin, obs::CLASS_NONE, 0, epoch as u64);
        // armed fault plans fire here (coordinator thread, before any
        // dispatch) so an injected panic unwinds cleanly through the epoch
        crate::fault::poke(crate::fault::FaultSite::Epoch);
        // cooperative cancellation: the once-per-epoch checkpoint
        if let Some(c) = &cfg.cancel {
            c.checkpoint(&label, epoch);
        }
        let shard = if use_interleaved { layout.shard(0) } else { None };
        rng.shuffle(&mut ids);
        for (i, &b) in ids.iter().enumerate() {
            // overlap the next bucket's memory fetch with this bucket's
            // compute (§3: bucketing makes prefetching effective; the
            // shuffled *bucket* order still defeats the hardware stream
            // detector, so we hint it explicitly)
            if let Some(sh) = shard {
                if let Some(&nb) = ids.get(i + 1) {
                    sh.prefetch_bucket(nb as usize);
                }
                kernel::run_bucket(
                    sh,
                    &obj,
                    buckets.range(b as usize),
                    &mut st.alpha,
                    &mut st.v,
                    &ds.y,
                    ds.norms(),
                    inv_lambda_n,
                    n,
                );
                continue;
            }
            if let Some(&nb) = ids.get(i + 1) {
                let r = buckets.range(nb as usize);
                ds.x.prefetch_cols(r.start, r.end);
            }
            run_bucket(
                ds,
                &obj,
                buckets.range(b as usize),
                &mut st.alpha,
                &mut st.v,
                inv_lambda_n,
                n,
            );
        }
        let rel = mon.observe(&st.alpha);
        let gap = if cfg.gap_tol.is_some() && epoch % cfg.gap_check_every == 0 {
            Some(crate::glm::duality_gap(ds, &obj, &st).gap)
        } else {
            None
        };
        let wall_s = t.elapsed_s();
        epochs.push(EpochStats {
            epoch,
            wall_s,
            rel_change: rel,
            gap,
            primal: None,
        });
        conv.record(epoch, wall_s, rel, gap, None, None);
        // Epoch-boundary tuning: feed the point just recorded, apply any
        // decisions before the next epoch starts.
        for d in tuner.observe(conv.points.last().expect("recorded this epoch")) {
            match d.knob {
                Knob::Layout => {
                    use_interleaved = d.to == "interleaved";
                    if use_interleaved && layout.shard(0).is_none() {
                        layout = RunLayout::resolve(true, None, |_| false, || {
                            ShardedLayout::single(&ds.x, &buckets)
                        });
                    }
                }
                Knob::Bucket => {
                    if let Ok(nb) = d.to.parse::<usize>() {
                        bucket_size = nb.max(1);
                        buckets = Buckets::new(n, bucket_size);
                        ids = buckets.ids();
                        if use_interleaved {
                            layout = RunLayout::resolve(true, None, |_| false, || {
                                ShardedLayout::single(&ds.x, &buckets)
                            });
                        }
                    }
                }
                Knob::Workers | Knob::Steal => {}
            }
        }
        epoch_ctr.inc();
        epoch_wall_us.record((wall_s * 1e6) as u64);
        obs::emit(EventKind::EpochEnd, obs::CLASS_NONE, 0, epoch as u64);
        if mon.converged() || gap.map(|g| g < cfg.gap_tol.unwrap()).unwrap_or(false) {
            converged = true;
            break;
        }
    }
    let record = RunRecord {
        solver: label,
        threads: 1,
        epochs,
        converged,
        diverged: false,
        total_wall_s: total.elapsed_s(),
    };
    TrainOutput::assemble(ds, &obj, st, record)
        .with_convergence(conv)
        .with_tune_log(tuner.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::solver::BucketPolicy;

    fn cfg(lambda: f64) -> SolverConfig {
        SolverConfig::new(Objective::Logistic { lambda })
            .with_tol(1e-5)
            .with_max_epochs(300)
    }

    #[test]
    fn converges_to_small_gap_dense() {
        let ds = synthetic::dense_classification(400, 20, 1);
        let out = train_sequential(&ds, &cfg(1.0 / 400.0));
        assert!(out.converged);
        assert!(out.final_gap < 1e-3, "gap={}", out.final_gap);
    }

    #[test]
    fn converges_sparse() {
        let ds = synthetic::sparse_classification(500, 100, 0.05, 2);
        let out = train_sequential(&ds, &cfg(1.0 / 500.0));
        assert!(out.converged);
        assert!(out.final_gap < 1e-3);
    }

    #[test]
    fn ridge_matches_normal_equations() {
        // tiny ridge problem solvable in closed form:
        // w* = (X Xᵀ/n + λ I)⁻¹ X y / n  for our P(w) = 1/(2n)Σ(xᵀw−y)² + λ/2‖w‖²
        let ds = synthetic::dense_regression(200, 3, 0.05, 3);
        let obj = Objective::Ridge { lambda: 0.1 };
        let c = SolverConfig::new(obj).with_tol(1e-10).with_max_epochs(2000);
        let out = train_sequential(&ds, &c);
        let w = out.weights(&obj);
        // gradient of primal at w* must vanish:
        // (1/n)Σ(xᵀw−y)x + λw = 0
        let n = ds.n();
        let mut grad = vec![0.0; 3];
        for j in 0..n {
            let r = ds.x.dot_col(j, &w) - ds.y[j];
            ds.x.axpy_col(j, r / n as f64, &mut grad);
        }
        for (g, wi) in grad.iter_mut().zip(&w) {
            *g += 0.1 * wi;
        }
        let gnorm = crate::util::norm_sq(&grad).sqrt();
        assert!(gnorm < 1e-4, "stationarity violated: |grad|={gnorm}");
    }

    #[test]
    fn hinge_converges() {
        let ds = synthetic::dense_classification(300, 10, 4);
        let obj = Objective::Hinge { lambda: 1.0 / 300.0 };
        let out = train_sequential(
            &ds,
            &SolverConfig::new(obj).with_tol(1e-6).with_max_epochs(500),
        );
        assert!(out.final_gap < 1e-2, "gap={}", out.final_gap);
        let idx: Vec<usize> = (0..300).collect();
        let acc = crate::glm::accuracy(&ds, &out.weights(&obj), &idx);
        assert!(acc > 0.9, "acc={acc}");
    }

    #[test]
    fn bucketed_and_unbucketed_reach_same_solution() {
        let ds = synthetic::dense_classification(600, 15, 5);
        let obj = Objective::Logistic { lambda: 1e-3 };
        let base = SolverConfig::new(obj).with_tol(1e-8).with_max_epochs(500);
        let a = train_sequential(&ds, &base.clone().with_bucket(BucketPolicy::Off));
        let b = train_sequential(&ds, &base.with_bucket(BucketPolicy::Fixed(8)));
        let wa = a.weights(&obj);
        let wb = b.weights(&obj);
        let dist = crate::util::rel_change(&wa, &wb);
        assert!(dist < 1e-3, "solutions differ: {dist}");
    }

    #[test]
    fn v_consistency_after_training() {
        let ds = synthetic::sparse_classification(200, 50, 0.1, 6);
        let out = train_sequential(&ds, &cfg(0.01));
        assert!(out.state.v_drift(&ds) < 1e-8);
    }

    #[test]
    fn respects_max_epochs() {
        let ds = synthetic::dense_classification(100, 10, 7);
        let c = cfg(1e-4).with_max_epochs(3).with_tol(1e-15);
        let out = train_sequential(&ds, &c);
        assert_eq!(out.epochs_run, 3);
        assert!(!out.converged);
    }

    #[test]
    fn gap_stop_triggers() {
        let ds = synthetic::dense_classification(200, 10, 8);
        let mut c = cfg(1.0 / 200.0).with_tol(1e-30); // never trips rel-change
        c.gap_tol = Some(1e-3);
        c.gap_check_every = 1;
        c.max_epochs = 500;
        let out = train_sequential(&ds, &c);
        assert!(out.converged);
        assert!(out.final_gap < 1e-3);
    }
}
