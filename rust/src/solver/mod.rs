//! The paper's training system: bucketed, dynamically-partitioned,
//! NUMA-hierarchical SDCA — plus the "wild" asynchronous baseline it is
//! measured against.
//!
//! Variant map (paper § → module):
//!
//! | paper                                  | module        |
//! |----------------------------------------|---------------|
//! | Algorithm 1, "wild" multi-threaded     | [`wild`]      |
//! | §3 single-threaded + buckets           | [`seq`]       |
//! | §3 multi-threaded, replicas + dynamic  | [`dom`]       |
//! | §3 numa-level hierarchical             | [`numa`]      |
//!
//! All variants share [`SolverConfig`] and produce a [`TrainOutput`] with a
//! per-epoch [`metrics::RunRecord`], so the figure harnesses can sweep them
//! uniformly. Convergence-vs-thread-count studies on arbitrary simulated
//! thread counts run through [`crate::vthread`].
//!
//! Data access: solvers stream either the shard-resident interleaved
//! layout ([`crate::data::shard`], the default) or the segment-chunked
//! source matrix through a [`ColCursor`](crate::data::ColCursor)
//! (`--layout csc`). Both are bit-wise identical by construction — every
//! dot path shares the one [`crate::util::dot4_by`] reduction. The layer
//! map and all determinism arguments (job-order merge across executors,
//! Interleaved==Csc bit-equality, immutable versioned serving snapshots)
//! are collected in `docs/ARCHITECTURE.md`.

pub mod bucket;
pub mod convergence;
pub mod dom;
pub mod exec;
pub mod kernel;
pub mod numa;
pub mod partition;
pub mod pool;
pub mod seq;
pub mod tune;
pub mod wild;

pub use bucket::{BucketPolicy, Buckets};
pub use convergence::ConvergenceMonitor;
pub use exec::{ExecPolicy, Executor};
pub use partition::Partitioning;
pub use pool::{ClassDelay, JobClass, PoolStats, QueueDelayReport, WorkerPool, WorkerStats};
pub use tune::{
    AutoTuner, CancelToken, Knob, TrainCancelled, TuneCaps, TuneDecision, TuneInit, TuneLog,
    TunePolicy, TUNE_LOG_MAGIC,
};

pub use crate::data::LayoutPolicy;

use crate::data::{DataMatrix, Dataset};
use crate::glm::{GapReport, ModelState, Objective};
use crate::metrics::RunRecord;
use crate::sysinfo::Topology;

/// Which trainer implementation to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// Single-threaded SDCA (optionally bucketed) — §3 "Single-Threaded".
    Sequential,
    /// Asynchronous shared-vector baseline — Algorithm 1.
    Wild,
    /// Per-thread replicas with static/dynamic partitioning — §3
    /// "Multi-threaded" ("domesticated" in the paper's terms).
    Domesticated,
    /// Hierarchical NUMA solver — §3 "Numa-level optimizations".
    Numa,
    /// Pick per the paper's runtime policy: sequential for 1 thread,
    /// domesticated within one node, numa across nodes.
    Auto,
}

/// How aggressively the replica solvers scale their local subproblem
/// (the CoCoA+ σ′ parameter).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SigmaPolicy {
    /// σ′ = K (number of workers): provably safe, conservative — local
    /// steps are damped K-fold, inflating epochs at high worker counts.
    Safe,
    /// Start from σ′ = max(1, K/4) and adapt per epoch with dual-value
    /// backtracking: revert + double σ′ when the merged dual got worse,
    /// gently relax σ′ toward 1 while epochs keep improving. Recovers the
    /// near-sequential epoch counts the paper reports for dynamic
    /// partitioning, while keeping the Safe fallback as the ceiling.
    Adaptive,
    /// Fixed override (expert knob; σ′ < safe can diverge).
    Fixed(f64),
}

/// Everything a training run needs besides the data.
#[derive(Clone, Debug)]
pub struct SolverConfig {
    pub obj: Objective,
    pub variant: Variant,
    pub threads: usize,
    pub max_epochs: usize,
    /// Convergence threshold on the relative model change per epoch.
    pub tol: f64,
    /// Optional duality-gap stop (checked every `gap_check_every` epochs).
    pub gap_tol: Option<f64>,
    pub gap_check_every: usize,
    pub seed: u64,
    pub bucket: BucketPolicy,
    pub partition: Partitioning,
    /// Replica merges per epoch for the domesticated solver (the paper
    /// synchronizes "periodically"; more merges = fresher replicas but
    /// `T·d` doubles of traffic each). `0` = auto: as many merges (≤8) as
    /// keep replica traffic under ~5% of the dataset streaming volume.
    pub merges_per_epoch: usize,
    /// σ′ policy for the replica solvers (see [`SigmaPolicy`]).
    pub sigma: SigmaPolicy,
    /// How worker jobs are executed (see [`ExecPolicy`]): the persistent
    /// NUMA-aware pool by default; `Shared` to reuse a session-owned pool
    /// across runs; `Threads` for spawn-per-round; `Sequential` for
    /// deterministic single-core runs. All of them produce bit-wise
    /// identical models.
    pub exec: ExecPolicy,
    /// Which data layout the inner loops stream (see [`LayoutPolicy`]):
    /// the shard-resident interleaved encoding with fused, prefetching
    /// bucket kernels by default, or the source matrix directly (`Csc`).
    /// Both produce bit-wise identical models — locked in by
    /// `rust/tests/pool_equivalence.rs`.
    pub layout: LayoutPolicy,
    /// Optional pre-built interleaved layout shared by the caller (a
    /// serving [`Session`](crate::serve::Session) keeps one resident for
    /// predicts). A solver reuses it instead of re-encoding the dataset
    /// when the geometry fits — `seq`/`dom` need a single shard with the
    /// run's exact bucket size, `wild` any single shard over the same
    /// examples; the hierarchical solver always builds its own per-node
    /// shards. Contents are identical to a fresh build, so the bit-wise
    /// guarantees are unaffected.
    pub layout_cache: Option<std::sync::Arc<crate::data::ShardedLayout>>,
    /// Optional warm start: resume from an existing [`ModelState`] instead
    /// of `α = 0` (serving-side partial refits after appending examples or
    /// changing λ). Honored by the `seq`/`dom`/`numa`/`wild` trainers; the
    /// state's dimensions must match the dataset or the run falls back to
    /// a cold start (logged). The `vthread` simulators ignore it.
    pub warm_start: Option<ModelState>,
    /// NUMA topology override (default: detect host).
    pub topology: Option<Topology>,
    /// Abort when the primal objective exceeds this multiple of its initial
    /// value (divergence detection for the wild solver).
    pub divergence_factor: f64,
    /// Online auto-tuning of bucket size / layout / workers (see
    /// [`tune`]). `Off` (the default) constructs no tuner and leaves the
    /// epoch loops bit-for-bit unchanged — locked by `rust/tests/tune.rs`.
    pub tune: TunePolicy,
    /// Optional cooperative cancellation token, checked once per epoch at
    /// the boundary checkpoint (see [`CancelToken`]). A cancelled run
    /// unwinds with a [`TrainCancelled`] panic payload that
    /// `serve::Session::guarded` converts into the typed
    /// `ServeError::Cancelled` after rolling the session back.
    pub cancel: Option<CancelToken>,
}

impl SolverConfig {
    pub fn new(obj: Objective) -> Self {
        SolverConfig {
            obj,
            variant: Variant::Auto,
            threads: 1,
            max_epochs: 200,
            tol: 1e-3,
            gap_tol: None,
            gap_check_every: 5,
            seed: 42,
            bucket: BucketPolicy::Auto,
            partition: Partitioning::Dynamic,
            merges_per_epoch: 0, // auto
            sigma: SigmaPolicy::Adaptive,
            exec: ExecPolicy::Pool,
            layout: LayoutPolicy::Interleaved,
            layout_cache: None,
            warm_start: None,
            topology: None,
            divergence_factor: 1e3,
            tune: TunePolicy::Off,
            cancel: None,
        }
    }

    pub fn with_threads(mut self, t: usize) -> Self {
        self.threads = t;
        self
    }

    pub fn with_variant(mut self, v: Variant) -> Self {
        self.variant = v;
        self
    }

    pub fn with_seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    pub fn with_tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }

    pub fn with_max_epochs(mut self, e: usize) -> Self {
        self.max_epochs = e;
        self
    }

    pub fn with_bucket(mut self, b: BucketPolicy) -> Self {
        self.bucket = b;
        self
    }

    pub fn with_partition(mut self, p: Partitioning) -> Self {
        self.partition = p;
        self
    }

    pub fn with_topology(mut self, t: Topology) -> Self {
        self.topology = Some(t);
        self
    }

    pub fn with_exec(mut self, e: ExecPolicy) -> Self {
        self.exec = e;
        self
    }

    pub fn with_layout(mut self, l: LayoutPolicy) -> Self {
        self.layout = l;
        self
    }

    /// Share a pre-built interleaved layout with this run (see
    /// [`SolverConfig::layout_cache`]).
    pub fn with_layout_cache(mut self, l: std::sync::Arc<crate::data::ShardedLayout>) -> Self {
        self.layout_cache = Some(l);
        self
    }

    /// Resume training from an existing model state (see
    /// [`SolverConfig::warm_start`]).
    pub fn with_warm_start(mut self, st: ModelState) -> Self {
        self.warm_start = Some(st);
        self
    }

    /// Enable or disable online auto-tuning (see [`SolverConfig::tune`]).
    pub fn with_tune(mut self, t: TunePolicy) -> Self {
        self.tune = t;
        self
    }

    /// Install a cooperative cancellation token (see
    /// [`SolverConfig::cancel`]).
    pub fn with_cancel(mut self, c: CancelToken) -> Self {
        self.cancel = Some(c);
        self
    }

    /// Build this run's executor (resolving [`ExecPolicy::Pool`] into a
    /// freshly spawned resident [`WorkerPool`] on `topo`). Called once per
    /// `train_*` entry point so the pool's workers persist across every
    /// epoch and merge round of the run.
    pub fn build_executor(&self, topo: &Topology) -> Executor {
        self.exec.build(self.threads.max(1), topo)
    }

    /// Resolve `merges_per_epoch = 0` (auto) for a dataset: as many merge
    /// rounds (capped at 8) as keep the replica merge traffic
    /// (`T·2·d·8 B` per merge) below ~5% of the per-epoch dataset
    /// streaming volume.
    pub fn resolve_merges<M: DataMatrix>(&self, ds: &Dataset<M>) -> usize {
        if self.merges_per_epoch > 0 {
            return self.merges_per_epoch;
        }
        let stream = ds.payload_bytes() as f64;
        let per_merge = (self.threads.max(1) * 2 * ds.d() * 8) as f64;
        ((0.05 * stream / per_merge) as usize).clamp(1, 8)
    }

    /// Resolve `Auto` into a concrete variant given a topology, following
    /// §3: sequential for one thread; domesticated while the threads fit on
    /// one node; numa-hierarchical otherwise.
    pub fn resolve_variant(&self, topo: &Topology) -> Variant {
        match self.variant {
            Variant::Auto => {
                if self.threads <= 1 {
                    Variant::Sequential
                } else if self.threads <= topo.cores_per_node[topo.data_node] {
                    Variant::Domesticated
                } else {
                    Variant::Numa
                }
            }
            v => v,
        }
    }
}

/// Resolve a run's starting [`ModelState`]: the configured warm start when
/// its shape matches the dataset, otherwise a cold `α = 0` start. A
/// mismatched warm state (e.g. examples were appended without extending
/// `α`) is rejected loudly (a `Warn`-level [`diag!`](crate::diag)) instead
/// of corrupting the run.
pub(crate) fn initial_state<M: DataMatrix>(cfg: &SolverConfig, ds: &Dataset<M>) -> ModelState {
    match &cfg.warm_start {
        Some(ws) if ws.alpha.len() == ds.n() && ws.v.len() == ds.d() => ws.clone(),
        Some(ws) => {
            crate::diag!(
                Warn,
                "parlin: warm-start shape ({} examples, {} features) does not match the \
                 dataset ({}, {}); cold-starting",
                ws.alpha.len(),
                ws.v.len(),
                ds.n(),
                ds.d()
            );
            ModelState::zeros(ds.n(), ds.d())
        }
        None => ModelState::zeros(ds.n(), ds.d()),
    }
}

/// Result of a training run: final state + run record.
pub struct TrainOutput {
    pub state: ModelState,
    pub record: RunRecord,
    pub epochs_run: usize,
    pub converged: bool,
    pub final_gap: f64,
    /// Primal objective at the final model (scale reference for the gap).
    pub final_primal: f64,
    /// Per-epoch convergence telemetry (gap / model change / wall clock /
    /// pool imbalance), an exact mirror of `record.epochs` — see
    /// [`crate::obs::ConvergenceTrace`]'s non-perturbation contract.
    pub convergence: crate::obs::ConvergenceTrace,
    /// The auto-tuner's replayable decision log: `Some` iff the run had
    /// [`TunePolicy::On`] (even when no decision fired), `None` under
    /// `Off`. Exported by the CLI via `--tune-log`.
    pub tune_log: Option<TuneLog>,
}

impl TrainOutput {
    pub(crate) fn assemble<M: DataMatrix>(
        ds: &Dataset<M>,
        obj: &Objective,
        state: ModelState,
        record: RunRecord,
    ) -> Self {
        let GapReport { gap, primal, .. } = crate::glm::duality_gap(ds, obj, &state);
        TrainOutput {
            epochs_run: record.epochs_run(),
            converged: record.converged,
            final_gap: gap,
            final_primal: primal,
            convergence: crate::obs::ConvergenceTrace::new(record.solver.clone(), record.threads),
            tune_log: None,
            state,
            record,
        }
    }

    /// Stamp the convergence trace a solver recorded (see
    /// [`TrainOutput::convergence`]).
    pub(crate) fn with_convergence(mut self, trace: crate::obs::ConvergenceTrace) -> Self {
        self.convergence = trace;
        self
    }

    /// Stamp the tuner's decision log (see [`TrainOutput::tune_log`]).
    pub(crate) fn with_tune_log(mut self, log: Option<TuneLog>) -> Self {
        self.tune_log = log;
        self
    }

    /// Primal weight vector of the trained model.
    pub fn weights(&self, obj: &Objective) -> Vec<f64> {
        self.state.w(obj)
    }
}

/// Train with the configured variant (the library's front door).
pub fn train<M: DataMatrix>(ds: &Dataset<M>, cfg: &SolverConfig) -> TrainOutput {
    let topo = cfg
        .topology
        .clone()
        .unwrap_or_else(Topology::detect);
    let variant = cfg.resolve_variant(&topo);
    // Pin the resolved topology so the per-variant entry points (which
    // also resolve it when called directly) never re-probe sysfs.
    let mut cfg = cfg.clone();
    cfg.topology = Some(topo.clone());
    match variant {
        Variant::Sequential => seq::train_sequential(ds, &cfg),
        Variant::Wild => wild::train_wild(ds, &cfg),
        Variant::Domesticated => dom::train_domesticated(ds, &cfg),
        Variant::Numa => numa::train_numa(ds, &cfg, &topo),
        Variant::Auto => unreachable!("resolve_variant never returns Auto"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn auto_resolution_follows_paper_policy() {
        let topo = Topology::uniform(4, 8);
        let cfg = SolverConfig::new(Objective::Logistic { lambda: 0.01 });
        assert_eq!(cfg.resolve_variant(&topo), Variant::Sequential);
        assert_eq!(
            cfg.clone().with_threads(4).resolve_variant(&topo),
            Variant::Domesticated
        );
        assert_eq!(
            cfg.clone().with_threads(16).resolve_variant(&topo),
            Variant::Numa
        );
    }

    #[test]
    fn front_door_trains() {
        let ds = synthetic::dense_classification(300, 10, 1);
        let cfg = SolverConfig::new(Objective::Logistic {
            lambda: 1.0 / 300.0,
        })
        .with_tol(1e-4);
        let out = train(&ds, &cfg);
        assert!(out.converged);
        assert!(out.final_gap < 1e-2, "gap={}", out.final_gap);
    }

    #[test]
    fn warm_start_resumes_instead_of_restarting() {
        let ds = synthetic::dense_classification(250, 10, 3);
        let cfg = SolverConfig::new(Objective::Logistic {
            lambda: 1.0 / 250.0,
        })
        .with_tol(1e-5)
        .with_max_epochs(400);
        let cold = train(&ds, &cfg);
        assert!(cold.converged);
        let warm = train(&ds, &cfg.clone().with_warm_start(cold.state.clone()));
        assert!(warm.converged);
        assert!(
            warm.epochs_run < cold.epochs_run,
            "warm {} vs cold {}",
            warm.epochs_run,
            cold.epochs_run
        );
        assert!(warm.final_gap <= cold.final_gap * 1.5 + 1e-12);
    }

    #[test]
    fn warm_start_honored_by_replica_solvers() {
        let ds = synthetic::dense_classification(300, 12, 4);
        let topo = Topology::uniform(2, 2);
        for variant in [Variant::Domesticated, Variant::Numa] {
            let cfg = SolverConfig::new(Objective::Logistic {
                lambda: 1.0 / 300.0,
            })
            .with_variant(variant)
            .with_threads(4)
            .with_topology(topo.clone())
            .with_tol(1e-4)
            .with_max_epochs(500);
            let cold = train(&ds, &cfg);
            assert!(cold.converged, "{variant:?} cold run must converge");
            let warm = train(&ds, &cfg.clone().with_warm_start(cold.state.clone()));
            assert!(
                warm.epochs_run <= 4,
                "{variant:?}: warm restart from the optimum ran {} epochs",
                warm.epochs_run
            );
            assert!(warm.epochs_run <= cold.epochs_run);
        }
    }

    #[test]
    fn mismatched_warm_start_falls_back_to_cold() {
        let ds = synthetic::dense_classification(120, 6, 5);
        let cfg = SolverConfig::new(Objective::Logistic {
            lambda: 1.0 / 120.0,
        })
        .with_warm_start(ModelState::zeros(7, 6)); // wrong n
        let st = initial_state(&cfg, &ds);
        assert_eq!(st.alpha.len(), 120);
        assert!(st.alpha.iter().all(|&a| a == 0.0));
    }
}
