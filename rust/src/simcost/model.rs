//! Per-epoch time estimation (DESIGN.md §5).
//!
//! The paper's per-epoch run-time on its testbeds is dominated by the
//! memory system; we decompose an epoch into additive/parallel terms
//! driven by exact workload counters:
//!
//! ```text
//!   t_epoch = max_over_threads(t_compute + t_stream + t_alpha + t_shared)
//!             + t_shuffle (serial)  + t_merge + t_reduce (barriers)
//! ```
//!
//! and evaluate them under a [`MachineModel`]. Every figure harness pairs
//! these times with *measured* epochs-to-converge from the real solvers /
//! the vthread engine: `time_to_convergence = epochs × t_epoch`.

use super::machines::MachineModel;
use crate::solver::Partitioning;

/// Static description of one dataset's per-epoch workload.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    pub n: usize,
    pub d: usize,
    pub nnz: usize,
    pub dense: bool,
}

impl Workload {
    pub fn of<M: crate::data::DataMatrix>(ds: &crate::data::Dataset<M>) -> Self {
        Workload {
            n: ds.n(),
            d: ds.d(),
            nnz: ds.x.nnz(),
            dense: ds.x.nnz() == ds.n() * ds.d(),
        }
    }

    /// Matrix payload bytes streamed per full epoch.
    pub fn stream_bytes(&self) -> f64 {
        if self.dense {
            (self.nnz * 8) as f64
        } else {
            (self.nnz * 12) as f64 // value + u32 index
        }
    }

    /// Model vector (`α`) bytes.
    pub fn alpha_bytes(&self) -> f64 {
        (self.n * 8) as f64
    }

    /// Shared vector bytes.
    pub fn v_bytes(&self) -> f64 {
        (self.d * 8) as f64
    }
}

/// Which trainer the estimate is for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverKind {
    Sequential,
    Wild,
    /// Replica solver; carries its partitioning scheme (same cost; the
    /// scheme changes epochs, not epoch time — except the shuffle length).
    Domesticated(Partitioning),
    Numa(Partitioning),
}

/// Per-epoch time breakdown, seconds.
#[derive(Clone, Copy, Debug, Default)]
pub struct TimeBreakdown {
    pub compute: f64,
    pub stream: f64,
    pub alpha: f64,
    pub shared: f64,
    pub shuffle: f64,
    pub merge: f64,
    pub reduce: f64,
}

impl TimeBreakdown {
    pub fn total(&self) -> f64 {
        self.compute
            + self.stream
            + self.alpha
            + self.shared
            + self.shuffle
            + self.merge
            + self.reduce
    }
}

/// Options mirrored from `SolverConfig` that affect epoch cost.
#[derive(Clone, Copy, Debug)]
pub struct CostOpts {
    pub threads: usize,
    pub bucket_size: usize,
    pub merges_per_epoch: usize,
    /// `true` when the solver places threads NUMA-aware (numa solver) —
    /// otherwise threads beyond the data node stream remotely (wild/dom
    /// naively spread by the OS).
    pub numa_aware: bool,
}

impl CostOpts {
    pub fn new(threads: usize) -> Self {
        CostOpts {
            threads,
            bucket_size: 1,
            merges_per_epoch: 0, // auto
            numa_aware: false,
        }
    }
}

/// Estimate one epoch of `kind` on `machine` for `w`.
pub fn epoch_time(
    machine: &MachineModel,
    w: &Workload,
    kind: SolverKind,
    opts: &CostOpts,
) -> TimeBreakdown {
    let threads = opts.threads.max(1) as f64;
    let placement = machine.topology.place_threads(opts.threads.max(1));
    let nodes_used = placement.iter().filter(|&&p| p > 0).count().max(1) as f64;
    let data_node = machine.topology.data_node;
    let mut b = TimeBreakdown::default();

    // ---- compute: 2 flops per nonzero (mul+add), perfectly parallel
    let flops = 2.0 * w.nnz as f64;
    b.compute = flops / threads / (machine.core_flops() * machine.compute_eff);

    // ---- dataset streaming: bytes/thread over the bandwidth the thread
    // actually sees. NUMA-aware solvers partition data so every node
    // streams locally; oblivious solvers keep the dataset on one node and
    // remote threads pull over the interconnect.
    let bytes = w.stream_bytes();
    if opts.numa_aware {
        // each node streams its share from local memory
        let per_node_bytes = bytes / nodes_used;
        b.stream = per_node_bytes / machine.stream_bw;
    } else {
        let local_threads = placement[data_node] as f64;
        let remote_threads = threads - local_threads;
        let local_bytes = bytes * local_threads / threads;
        let remote_bytes = bytes * remote_threads / threads;
        let t_local = local_bytes / machine.stream_bw;
        // remote threads share the interconnect
        let t_remote = if remote_threads > 0.0 {
            remote_bytes / machine.remote_bw
        } else {
            0.0
        };
        b.stream = t_local.max(t_remote);
    }

    // ---- α accesses: one line transfer per *bucket* when α misses the
    // LLC, else (cheap) LLC hits. Random order ⇒ no spatial reuse beyond
    // the bucket.
    let alpha_in_llc = w.alpha_bytes() <= machine.llc_bytes as f64;
    let line_hits = (w.n as f64 / opts.bucket_size.max(1) as f64) / threads;
    let alpha_line_cost = if alpha_in_llc {
        machine.local_line_s * 0.15 // L3 hit ≈ a few ns
    } else {
        machine.local_line_s
    };
    b.alpha = line_hits * alpha_line_cost;

    // ---- shared-vector traffic
    let lines_per_update = if w.dense {
        (w.v_bytes() / machine.cache_line as f64).ceil()
    } else {
        // scattered single-element touches: one line each
        w.nnz as f64 / w.n as f64
    };
    match kind {
        SolverKind::Wild => {
            // True-sharing ping-pong on the single shared v. A line only
            // costs a coherence transfer when another thread's RMW of the
            // *same line* is in flight concurrently; the collision window
            // is the line-transfer latency itself, compared against the
            // duration of one coordinate step:
            //
            //   p_true ≈ min(1, (T−1)·l·t_line / (V·t_step))
            //
            // with l = lines touched per step, V = total v lines. Dense
            // data (l = V) saturates p_true almost immediately — the
            // Fig. 1a regime; uniform sparse data keeps it low (Fig. 1b).
            // Contended transfers of one line serialize; distinct lines
            // ping-pong in parallel, so the epoch pays the per-line queue:
            //
            //   t_shared = (n·l/V) · p_true · t_line
            if threads > 1.0 {
                let local_frac = if nodes_used <= 1.0 {
                    1.0
                } else {
                    (placement[data_node] as f64 / threads).min(1.0)
                };
                let line_cost = local_frac * machine.local_line_s * 0.4 // intra-node: L3-to-L3
                    + (1.0 - local_frac) * machine.remote_line_s;
                let v_lines = (w.v_bytes() / machine.cache_line as f64).ceil().max(1.0);
                let step_s = 2.0 * (w.nnz as f64 / w.n as f64)
                    / (machine.core_flops() * machine.compute_eff)
                    + (w.stream_bytes() / w.n as f64) / machine.stream_bw;
                let p_true = ((threads - 1.0) * lines_per_update * line_cost
                    / (v_lines * step_s.max(1e-12)))
                .min(1.0);
                b.shared = (w.n as f64 * lines_per_update / v_lines) * p_true * line_cost;
            }
        }
        SolverKind::Sequential => {
            // v stays hot in this core's cache; charge only when it
            // doesn't fit in LLC (criteo-scale d)
            if w.v_bytes() > machine.llc_bytes as f64 {
                let steps = w.n as f64;
                b.shared = steps * lines_per_update * machine.local_line_s * 0.3;
            }
        }
        SolverKind::Domesticated(_) | SolverKind::Numa(_) => {
            // private replicas: no cross-thread traffic during the epoch;
            // replica beyond-LLC penalty as sequential
            if w.v_bytes() > machine.llc_bytes as f64 {
                let steps = w.n as f64 / threads;
                b.shared = steps * lines_per_update * machine.local_line_s * 0.3;
            }
        }
    }

    // ---- serial shuffle: Fisher–Yates over n/bucket indices on one
    // thread (the Fig. 2a serial bottleneck), ~8 cycles per swap.
    let shuffle_len = match kind {
        SolverKind::Wild | SolverKind::Sequential => w.n as f64,
        SolverKind::Domesticated(Partitioning::Dynamic)
        | SolverKind::Numa(Partitioning::Dynamic) => w.n as f64 / opts.bucket_size.max(1) as f64,
        SolverKind::Domesticated(Partitioning::Static) | SolverKind::Numa(Partitioning::Static) => {
            // per-worker local shuffles run in parallel
            w.n as f64 / opts.bucket_size.max(1) as f64 / threads
        }
    };
    // sequential solver shuffles buckets too
    let shuffle_len = if matches!(kind, SolverKind::Sequential) {
        w.n as f64 / opts.bucket_size.max(1) as f64
    } else {
        shuffle_len
    };
    b.shuffle = shuffle_len * 8.0 / (machine.ghz * 1e9);

    // ---- merges (replica solvers): every worker writes + reads d
    // doubles per merge through shared memory. merges_per_epoch = 0 means
    // auto (mirrors SolverConfig::resolve_merges).
    if matches!(kind, SolverKind::Domesticated(_) | SolverKind::Numa(_)) {
        let merges = if opts.merges_per_epoch == 0 {
            let per_merge = threads * 2.0 * w.v_bytes();
            ((0.05 * w.stream_bytes() / per_merge) as usize).clamp(1, 8) as f64
        } else {
            opts.merges_per_epoch as f64
        };
        b.merge = merges * (threads * 2.0 * w.v_bytes()) / machine.stream_bw
            + merges * 2e-6 * threads; // barrier latency
    }

    // ---- cross-node reduce (numa solver)
    if matches!(kind, SolverKind::Numa(_)) && nodes_used > 1.0 {
        b.reduce = (nodes_used - 1.0) * 2.0 * w.v_bytes() / machine.remote_bw + 5e-6 * nodes_used;
    }

    b
}

/// Convenience: total seconds per epoch.
pub fn epoch_seconds(
    machine: &MachineModel,
    w: &Workload,
    kind: SolverKind,
    opts: &CostOpts,
) -> f64 {
    epoch_time(machine, w, kind, opts).total()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcost::machines::{power9, xeon4};

    fn dense_100k() -> Workload {
        Workload {
            n: 100_000,
            d: 100,
            nnz: 10_000_000,
            dense: true,
        }
    }

    fn sparse_100k() -> Workload {
        Workload {
            n: 100_000,
            d: 1000,
            nnz: 1_000_000,
            dense: false,
        }
    }

    #[test]
    fn wild_dense_does_not_scale_past_one_node() {
        let m = xeon4();
        let w = dense_100k();
        let t1 = epoch_seconds(&m, &w, SolverKind::Wild, &CostOpts::new(1));
        let t8 = epoch_seconds(&m, &w, SolverKind::Wild, &CostOpts::new(8));
        let t32 = epoch_seconds(&m, &w, SolverKind::Wild, &CostOpts::new(32));
        // dense wild barely scales even within a node (Fig 1a)…
        assert!(t8 > t1 / 3.0, "t1={t1} t8={t8}");
        // …and multi-node coherence makes it drastically worse
        assert!(t32 > 2.0 * t8, "expected multi-node wild slowdown: t8={t8} t32={t32}");
        assert!(t32 > t1, "t32={t32} should not beat sequential t1={t1}");
    }

    #[test]
    fn wild_sparse_scales_on_one_node() {
        let m = xeon4();
        let w = sparse_100k();
        let t1 = epoch_seconds(&m, &w, SolverKind::Wild, &CostOpts::new(1));
        let t8 = epoch_seconds(&m, &w, SolverKind::Wild, &CostOpts::new(8));
        let t32 = epoch_seconds(&m, &w, SolverKind::Wild, &CostOpts::new(32));
        assert!(t8 < t1 / 2.0, "sparse wild should scale on one node: {t1} -> {t8}");
        assert!(t32 > t8, "multi-node should deteriorate sparse too: {t8} -> {t32}");
    }

    #[test]
    fn domesticated_scales_better_than_wild_on_dense() {
        let m = xeon4();
        let w = dense_100k();
        let opts = CostOpts {
            threads: 32,
            bucket_size: 8,
            merges_per_epoch: 1,
            numa_aware: true,
        };
        let dom = epoch_seconds(&m, &w, SolverKind::Numa(Partitioning::Dynamic), &opts);
        let wild = epoch_seconds(&m, &w, SolverKind::Wild, &CostOpts::new(32));
        assert!(dom * 3.0 < wild, "dom={dom} wild={wild}");
    }

    #[test]
    fn buckets_cut_alpha_and_shuffle_terms() {
        let m = xeon4();
        // model with n beyond LLC: 10M examples
        let w = Workload {
            n: 10_000_000,
            d: 28,
            nnz: 280_000_000,
            dense: true,
        };
        let no_bucket = epoch_time(&m, &w, SolverKind::Sequential, &CostOpts::new(1));
        let mut o = CostOpts::new(1);
        o.bucket_size = 8;
        let bucket = epoch_time(&m, &w, SolverKind::Sequential, &o);
        assert!(bucket.alpha < no_bucket.alpha / 7.0);
        assert!(bucket.shuffle < no_bucket.shuffle / 7.0);
        assert!(bucket.total() < no_bucket.total());
    }

    #[test]
    fn numa_aware_streaming_beats_oblivious_across_nodes() {
        let m = xeon4();
        let w = dense_100k();
        let mut aware = CostOpts::new(32);
        aware.numa_aware = true;
        let mut obliv = CostOpts::new(32);
        obliv.numa_aware = false;
        let ta = epoch_time(&m, &w, SolverKind::Numa(Partitioning::Dynamic), &aware);
        let to = epoch_time(&m, &w, SolverKind::Domesticated(Partitioning::Dynamic), &obliv);
        assert!(ta.stream < to.stream, "aware={:?} obliv={:?}", ta.stream, to.stream);
    }

    #[test]
    fn power9_faster_wild_than_xeon_at_same_threads() {
        // the paper: "wild exhibits significantly better performance on the
        // 2-node system … due to increased memory bandwidth"
        let w = dense_100k();
        let tx = epoch_seconds(&xeon4(), &w, SolverKind::Wild, &CostOpts::new(16));
        let tp = epoch_seconds(&power9(), &w, SolverKind::Wild, &CostOpts::new(16));
        assert!(tp < tx, "p9={tp} xeon={tx}");
    }

    #[test]
    fn breakdown_total_is_sum() {
        let m = xeon4();
        let w = dense_100k();
        let b = epoch_time(&m, &w, SolverKind::Sequential, &CostOpts::new(1));
        let sum = b.compute + b.stream + b.alpha + b.shared + b.shuffle + b.merge + b.reduce;
        assert!((b.total() - sum).abs() < 1e-15);
        assert!(b.total() > 0.0);
    }
}
