//! Machine models of the paper's two testbeds (§4):
//!
//! * a 4-node Intel Xeon E5-4620 (8 physical cores/node, 2.2 GHz, AVX,
//!   64 B lines, 16 MiB LLC/socket, QPI interconnect),
//! * a 2-node IBM POWER9 (3.8 GHz, VSX, 128 B lines, large L3, high
//!   memory bandwidth — the paper repeatedly attributes the 2-node
//!   machine's better "wild" behaviour to it).
//!
//! Parameters are public microarchitecture figures, not measurements of
//! the authors' boxes; the cost model's goal is the *shape* of the paper's
//! curves (who wins, where scaling knees sit), per DESIGN.md §4/§5.

use crate::sysinfo::Topology;

/// Cost-model description of a multi-socket CPU machine.
#[derive(Clone, Debug)]
pub struct MachineModel {
    pub name: &'static str,
    pub topology: Topology,
    /// Core clock in GHz (paper pins the frequency).
    pub ghz: f64,
    /// f64 SIMD lanes per core.
    pub simd_f64_lanes: f64,
    /// Fused multiply-add available?
    pub fma: bool,
    /// Fraction of SIMD peak the streaming inner-product loop achieves.
    pub compute_eff: f64,
    /// Cache line size in bytes.
    pub cache_line: usize,
    /// Last-level cache per node, bytes.
    pub llc_bytes: usize,
    /// Streaming bandwidth per node, bytes/s (shared by its cores).
    pub stream_bw: f64,
    /// Cross-node streaming bandwidth, bytes/s (interconnect).
    pub remote_bw: f64,
    /// Latency to fetch a line that is LLC/memory-local, seconds.
    pub local_line_s: f64,
    /// Latency to fetch/invalidate a line held by a remote node, seconds.
    pub remote_line_s: f64,
    /// Pairwise probability that two unsynchronized same-element RMWs
    /// collide when the threads share a node / sit on different nodes
    /// (feeds `vthread::WildSimParams`).
    pub p_collide_local: f64,
    pub p_collide_remote: f64,
}

impl MachineModel {
    /// Peak f64 FLOP/s of one core.
    pub fn core_flops(&self) -> f64 {
        self.ghz * 1e9 * self.simd_f64_lanes * if self.fma { 2.0 } else { 1.0 }
    }

    /// α-entries per cache line (the bucket size the paper derives).
    pub fn entries_per_line(&self) -> usize {
        self.cache_line / std::mem::size_of::<f64>()
    }

    /// Collision parameters for the wild convergence simulator.
    pub fn wild_params(&self, _threads: usize) -> crate::vthread::WildSimParams {
        crate::vthread::WildSimParams {
            p_collide_local: self.p_collide_local,
            p_collide_remote: self.p_collide_remote,
            topology: self.topology.clone(),
        }
    }
}

/// The paper's 4-node Xeon E5-4620 ("x86", 2.2 GHz, 32 cores total).
pub fn xeon4() -> MachineModel {
    MachineModel {
        name: "xeon4",
        topology: Topology::uniform(4, 8),
        ghz: 2.2,
        simd_f64_lanes: 4.0, // AVX
        fma: false,          // Sandy Bridge EP: no FMA3
        compute_eff: 0.55,
        cache_line: 64,
        llc_bytes: 16 << 20,
        stream_bw: 38e9,
        remote_bw: 12e9, // QPI per link, effective
        local_line_s: 80e-9,
        remote_line_s: 300e-9,
        // intra-node RMWs are serialized by MESI ownership — losses are
        // effectively a cross-node phenomenon (deep coherence windows)
        p_collide_local: 0.0,
        p_collide_remote: 0.06,
    }
}

/// The paper's 2-node POWER9 (3.8 GHz, SMT off; 2 × 20 cores).
pub fn power9() -> MachineModel {
    MachineModel {
        name: "power9",
        topology: Topology::uniform(2, 20),
        ghz: 3.8,
        simd_f64_lanes: 2.0, // VSX
        fma: true,
        compute_eff: 0.6,
        cache_line: 128,
        llc_bytes: 100 << 20, // 10 MiB L3 per core pair, huge effective LLC
        stream_bw: 110e9,     // the "increased memory bandwidth" the paper cites
        remote_bw: 60e9,      // SMP X-bus
        local_line_s: 60e-9,
        remote_line_s: 180e-9,
        p_collide_local: 0.0,
        p_collide_remote: 0.04, // stronger X-bus than QPI
    }
}

/// Both paper testbeds (the order figures iterate in).
pub fn paper_machines() -> Vec<MachineModel> {
    vec![xeon4(), power9()]
}

/// A machine model for *this* host (used when the user wants measured-vs-
/// modeled comparisons locally).
pub fn host() -> MachineModel {
    let topo = Topology::detect();
    MachineModel {
        name: "host",
        topology: topo,
        ghz: 2.5,
        simd_f64_lanes: 4.0,
        fma: true,
        compute_eff: 0.5,
        cache_line: crate::sysinfo::cache_line_size(),
        llc_bytes: crate::sysinfo::llc_size(),
        stream_bw: 20e9,
        remote_bw: 20e9,
        local_line_s: 90e-9,
        remote_line_s: 90e-9,
        p_collide_local: 0.0,
        p_collide_remote: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_specs() {
        let x = xeon4();
        assert_eq!(x.topology.num_nodes(), 4);
        assert_eq!(x.topology.total_cores(), 32);
        assert_eq!(x.entries_per_line(), 8);
        let p = power9();
        assert_eq!(p.topology.num_nodes(), 2);
        assert_eq!(p.entries_per_line(), 16);
        assert!(p.stream_bw > x.stream_bw, "paper: P9 has more bandwidth");
    }

    #[test]
    fn peak_flops_sane() {
        // E5-4620 AVX: 2.2e9 · 4 = 8.8 GFLOP/s/core
        assert!((xeon4().core_flops() - 8.8e9).abs() < 1e6);
        // P9 VSX FMA: 3.8e9 · 2 · 2 = 15.2
        assert!((power9().core_flops() - 15.2e9).abs() < 1e6);
    }

    #[test]
    fn host_detects() {
        let h = host();
        assert!(h.topology.total_cores() >= 1);
        assert!(h.llc_bytes > 0);
    }
}
