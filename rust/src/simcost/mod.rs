//! Machine cost model — the substitute for the paper's physical testbeds
//! (DESIGN.md §4/§5).
//!
//! `epochs-to-converge` in every figure comes from *really executing* the
//! algorithms (`solver::`, `vthread::`); this module supplies the other
//! factor, per-epoch wall-clock on the paper's machines:
//!
//! ```text
//!   time_to_convergence(solver, T, machine) =
//!       epochs(solver, T)              // measured, exact
//!     × epoch_time(solver, T, machine) // modeled here
//! ```

pub mod machines;
pub mod model;

pub use machines::{host, paper_machines, power9, xeon4, MachineModel};
pub use model::{epoch_seconds, epoch_time, CostOpts, SolverKind, TimeBreakdown, Workload};
