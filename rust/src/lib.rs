//! # parlin — Parallel training of linear models without compromising convergence
//!
//! A full-system reproduction of Ioannou, Dünner, Kourtis & Parnell (2018):
//! system-aware stochastic dual coordinate ascent (SDCA) for generalized
//! linear models on multi-core, multi-NUMA-node CPUs.
//!
//! The library is organized in three layers:
//!
//! * **L3 — rust coordinator** (this crate): the paper's contribution — the
//!   bucketed, dynamically-partitioned, NUMA-hierarchical SDCA trainer, the
//!   "wild" asynchronous baseline it improves on, the Fig. 6 comparator
//!   solvers (L-BFGS, SAG, dual CD, IRLSM), a virtual-thread execution
//!   engine that reproduces parallel convergence behaviour deterministically
//!   on any core count, and a machine cost model for the paper's testbeds.
//! * **L2 — JAX model** (`python/compile/model.py`, build time only): dense
//!   bulk compute (prediction, loss/metric and gradient evaluation) lowered
//!   AOT to HLO text.
//! * **L1 — Pallas kernels** (`python/compile/kernels/`): the tiled matvec /
//!   fused loss kernels called by L2, validated against a pure-jnp oracle.
//!
//! At run time the rust binary is self-contained: `runtime` loads the HLO
//! artifacts via PJRT (`xla` crate) — Python is never on the training path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use parlin::data::synthetic;
//! use parlin::glm::Objective;
//! use parlin::solver::{SolverConfig, train};
//!
//! let ds = synthetic::dense_classification(10_000, 100, 42);
//! let cfg = SolverConfig::new(Objective::Logistic { lambda: 1.0 / ds.n() as f64 });
//! let out = train(&ds, &cfg);
//! println!("converged in {} epochs, gap {:.3e}", out.epochs_run, out.final_gap);
//! ```

pub mod baselines;
pub mod data;
pub mod fault;
pub mod figures;
pub mod glm;
pub mod metrics;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod simcost;
pub mod solver;
pub mod sysinfo;
pub mod util;
pub mod vthread;
