//! Deterministic fault injection for the serve tier.
//!
//! A [`FaultPlan`] names *where* ([`FaultSite`]) and *when* (the k-th hit
//! of that site) a failure fires, and *what* fires ([`FaultAction`]):
//! a panic, a typed error, NaN-poisoned weights, or a delay. Plans are
//! parsed from a compact spec (`--fault-plan` on the CLI, see the grammar
//! on [`FaultPlan::parse`]) and armed process-wide with
//! [`FaultPlan::arm`]; the returned [`FaultGuard`] disarms on drop.
//!
//! Determinism: hit counters are plain per-site sequence numbers — the
//! k-th time the process reaches a site is the k-th hit, independent of
//! wall clock — and the plan's `seed` fixes any value choice the injected
//! fault makes (today: which weight coordinate a `nan` action poisons).
//! The same plan against the same request stream reproduces the same
//! failure.
//!
//! Zero cost when off, by the same discipline as [`crate::obs`]: every
//! [`poke`] is ONE relaxed atomic load of the `ARMED` flag when no plan is
//! armed; the counter bump, rule match, and action dispatch live in a
//! `#[cold]` slow path that is never entered while disarmed (the pool
//! unit test `faults_disarmed_cost_one_relaxed_load` locks this in, the
//! same pattern as `tracing_off_builds_no_rings`).
//!
//! Arming is test-serialized exactly like trace sessions: `arm()` holds a
//! process-wide mutex for the guard's lifetime, so two armed-plan tests
//! in one binary cannot interleave, and [`disarmed`] lets a test hold the
//! same exclusion *without* arming anything.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::util::lock_recover;

/// Named injection points, in the order the serve tier reaches them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// Top of every solver epoch (all four variants), on the thread that
    /// called `train` — a mid-refit failure inside the optimizer.
    Epoch,
    /// Entry of the background drain thread's body, before it takes the
    /// staged batch — a drain-thread death.
    Drain,
    /// Just before a freshly trained model is installed in the session —
    /// the last instant a divergent/poisoned model could slip past the
    /// health gate.
    Publish,
}

impl FaultSite {
    pub const ALL: [FaultSite; 3] = [FaultSite::Epoch, FaultSite::Drain, FaultSite::Publish];

    pub fn name(self) -> &'static str {
        match self {
            FaultSite::Epoch => "epoch",
            FaultSite::Drain => "drain",
            FaultSite::Publish => "publish",
        }
    }

    fn index(self) -> usize {
        match self {
            FaultSite::Epoch => 0,
            FaultSite::Drain => 1,
            FaultSite::Publish => 2,
        }
    }

    fn parse(s: &str) -> Result<FaultSite> {
        match s {
            "epoch" | "solver-epoch" => Ok(FaultSite::Epoch),
            "drain" => Ok(FaultSite::Drain),
            "publish" => Ok(FaultSite::Publish),
            other => bail!("unknown fault site '{other}' (known: epoch, drain, publish)"),
        }
    }
}

impl std::fmt::Display for FaultSite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// What fires when a rule matches.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultAction {
    /// `panic!` with a message — models a genuine bug in the refit path.
    Panic,
    /// `panic_any(InjectedFault)` — an unwinding failure the containment
    /// layer recognizes and maps to `ServeError::Injected` instead of
    /// `RefitPanicked`, so tests can tell "injected" from "real".
    Error,
    /// Poison one weight coordinate (picked by the plan seed) with NaN
    /// just before install — must be caught by the publish health gate.
    /// Only meaningful at [`FaultSite::Publish`]; rejected elsewhere at
    /// parse time.
    Nan,
    /// Sleep in place — models a stuck (not dead) stage for watchdog
    /// tests.
    Delay(Duration),
}

/// One `action@site[#k][xN]` clause: fire `action` on hits `k..k+n` of
/// `site` (both default to 1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultRule {
    pub site: FaultSite,
    pub action: FaultAction,
    /// 1-based hit index of the first firing.
    pub at: u64,
    /// How many consecutive hits fire (so a retried operation can be made
    /// to exhaust its retry budget deterministically).
    pub count: u64,
}

impl FaultRule {
    fn matches(&self, site: FaultSite, hit: u64) -> bool {
        self.site == site && hit >= self.at && hit < self.at + self.count
    }
}

/// A parsed, seeded fault plan. Inert until [`FaultPlan::arm`]ed.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    rules: Vec<FaultRule>,
    seed: u64,
}

impl FaultPlan {
    /// Parse a spec: clauses separated by `;` or `,`, each
    /// `action@site[#k][xN]`.
    ///
    /// * actions — `panic`, `error`, `nan` (publish site only),
    ///   `delay:<ms>`
    /// * sites — `epoch` (alias `solver-epoch`), `drain`, `publish`
    /// * `#k` — fire on the k-th hit of the site (1-based, default 1)
    /// * `xN` — keep firing for N consecutive hits (default 1; use this
    ///   to outlast a retry budget, e.g. `panic@epoch#1x8`)
    ///
    /// `seed` fixes any value choice an action makes (the NaN coordinate).
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan> {
        let mut rules = Vec::new();
        for clause in spec.split([';', ',']).map(str::trim).filter(|c| !c.is_empty()) {
            let (action_s, rest) = clause
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("fault clause '{clause}' has no '@site'"))?;
            let action = match action_s {
                "panic" => FaultAction::Panic,
                "error" => FaultAction::Error,
                "nan" => FaultAction::Nan,
                other => match other.strip_prefix("delay:") {
                    Some(ms) => {
                        let ms: u64 = ms
                            .parse()
                            .map_err(|e| anyhow::anyhow!("bad delay '{other}': {e}"))?;
                        FaultAction::Delay(Duration::from_millis(ms))
                    }
                    None => bail!(
                        "unknown fault action '{other}' (known: panic, error, nan, delay:<ms>)"
                    ),
                },
            };
            let (site_s, at, count) = match rest.split_once('#') {
                None => (rest, 1, 1),
                Some((site_s, tail)) => {
                    let (at_s, count_s) = match tail.split_once('x') {
                        None => (tail, None),
                        Some((a, c)) => (a, Some(c)),
                    };
                    let at: u64 = at_s
                        .parse()
                        .map_err(|e| anyhow::anyhow!("bad hit index in '{clause}': {e}"))?;
                    if at == 0 {
                        bail!("hit index in '{clause}' is 1-based, got #0");
                    }
                    let count: u64 = match count_s {
                        None => 1,
                        Some(c) => c
                            .parse()
                            .map_err(|e| anyhow::anyhow!("bad repeat count in '{clause}': {e}"))?,
                    };
                    if count == 0 {
                        bail!("repeat count in '{clause}' must be >= 1");
                    }
                    (site_s, at, count)
                }
            };
            let site = FaultSite::parse(site_s)?;
            if action == FaultAction::Nan && site != FaultSite::Publish {
                bail!("'nan' only injects at the publish site (got '{clause}')");
            }
            rules.push(FaultRule { site, action, at, count });
        }
        if rules.is_empty() {
            bail!("fault plan '{spec}' contains no clauses");
        }
        Ok(FaultPlan { rules, seed })
    }

    pub fn rules(&self) -> &[FaultRule] {
        &self.rules
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Arm this plan process-wide. Holds the fault session (a mutex, like
    /// trace sessions) until the guard drops, which disarms and clears
    /// the plan.
    pub fn arm(self) -> FaultGuard {
        let serial = lock_recover(&SESSION);
        *lock_recover(&PLAN) = Some(Arc::new(PlanState {
            plan: self,
            hits: [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)],
        }));
        ARMED.store(true, Ordering::SeqCst);
        FaultGuard { _serial: serial }
    }
}

/// The marker payload `FaultAction::Error` unwinds with; the containment
/// layer downcasts for it to classify the failure as injected.
#[derive(Clone, Copy, Debug)]
pub struct InjectedFault {
    pub site: &'static str,
}

struct PlanState {
    plan: FaultPlan,
    /// Per-site hit counters (indexed by `FaultSite::index`).
    hits: [AtomicU64; 3],
}

/// One relaxed load on every hot-path [`poke`]; flipped only by
/// [`FaultPlan::arm`] / [`FaultGuard`] drop.
static ARMED: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<Arc<PlanState>>> = Mutex::new(None);
/// Serializes armed sessions (and [`disarmed`] exclusions) across tests.
static SESSION: Mutex<()> = Mutex::new(());

/// RAII armed-plan session; disarms and clears the plan on drop.
pub struct FaultGuard {
    _serial: MutexGuard<'static, ()>,
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        ARMED.store(false, Ordering::SeqCst);
        *lock_recover(&PLAN) = None;
    }
}

/// Hold the fault session **without** arming a plan — the analogue of
/// `TraceSession::start(ObsConfig::off())`: a test asserting the disarmed
/// path takes this so an armed-plan test in the same binary cannot race
/// it.
pub fn disarmed() -> FaultGuard {
    FaultGuard { _serial: lock_recover(&SESSION) }
}

/// Is a plan currently armed?
pub fn armed() -> bool {
    ARMED.load(Ordering::SeqCst)
}

/// Hits recorded at `site` by the armed plan (0 when disarmed — disarmed
/// pokes never reach the counter, which is what the zero-cost-off test
/// asserts).
pub fn hits(site: FaultSite) -> u64 {
    match lock_recover(&PLAN).as_ref() {
        Some(state) => state.hits[site.index()].load(Ordering::SeqCst),
        None => 0,
    }
}

/// Which weight coordinate a `nan` action poisons: fixed by the plan
/// seed. 0 when no plan is armed (callers only ask after a `Nan` poke).
pub fn poison_index(d: usize) -> usize {
    let seed = lock_recover(&PLAN).as_ref().map(|s| s.plan.seed).unwrap_or(0);
    (seed % d.max(1) as u64) as usize
}

/// The injection point: call at a [`FaultSite`]. Disarmed this is one
/// relaxed atomic load. Armed, it bumps the site's hit counter and, when
/// a rule matches, fires: `Panic`/`Error` unwind from here, `Delay`
/// sleeps in place and returns `None`, and `Nan` returns
/// `Some(FaultAction::Nan)` for the caller (the session's install path)
/// to apply — the poke itself cannot reach the weights.
#[inline]
pub fn poke(site: FaultSite) -> Option<FaultAction> {
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    poke_armed(site)
}

#[cold]
fn poke_armed(site: FaultSite) -> Option<FaultAction> {
    let state = match lock_recover(&PLAN).as_ref() {
        Some(state) => Arc::clone(state),
        // a guard is mid-drop: ARMED read raced the plan clear
        None => return None,
    };
    let hit = state.hits[site.index()].fetch_add(1, Ordering::SeqCst) + 1;
    let rule = state.plan.rules.iter().find(|r| r.matches(site, hit))?;
    match rule.action {
        FaultAction::Panic => panic!("fault injection: panic@{site}#{hit}"),
        FaultAction::Error => {
            std::panic::panic_any(InjectedFault { site: site.name() })
        }
        FaultAction::Delay(d) => {
            std::thread::sleep(d);
            None
        }
        FaultAction::Nan => Some(FaultAction::Nan),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_full_grammar() {
        let plan = FaultPlan::parse("panic@epoch#2x3; delay:50@drain, nan@publish#1x8", 9).unwrap();
        assert_eq!(plan.seed(), 9);
        assert_eq!(
            plan.rules(),
            &[
                FaultRule {
                    site: FaultSite::Epoch,
                    action: FaultAction::Panic,
                    at: 2,
                    count: 3
                },
                FaultRule {
                    site: FaultSite::Drain,
                    action: FaultAction::Delay(Duration::from_millis(50)),
                    at: 1,
                    count: 1
                },
                FaultRule { site: FaultSite::Publish, action: FaultAction::Nan, at: 1, count: 8 },
            ]
        );
        // the solver-epoch alias maps to the same site
        let alias = FaultPlan::parse("error@solver-epoch", 0).unwrap();
        assert_eq!(alias.rules()[0].site, FaultSite::Epoch);
        assert_eq!(alias.rules()[0].action, FaultAction::Error);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "",
            "panic",                // no site
            "panic@nowhere",        // unknown site
            "explode@epoch",        // unknown action
            "panic@epoch#0",        // hit indices are 1-based
            "panic@epoch#1x0",      // zero repeat
            "delay:abc@drain",      // bad millis
            "nan@epoch",            // nan only makes sense at publish
        ] {
            assert!(FaultPlan::parse(bad, 1).is_err(), "'{bad}' must not parse");
        }
    }

    #[test]
    fn hits_sequence_and_rule_windows_fire_deterministically() {
        let _g = {
            // fire on publish hits 2 and 3 only
            FaultPlan::parse("nan@publish#2x2", 4).unwrap().arm()
        };
        assert!(armed());
        assert_eq!(poke(FaultSite::Publish), None, "hit 1 is before the window");
        assert_eq!(poke(FaultSite::Publish), Some(FaultAction::Nan), "hit 2 fires");
        assert_eq!(poke(FaultSite::Publish), Some(FaultAction::Nan), "hit 3 fires");
        assert_eq!(poke(FaultSite::Publish), None, "hit 4 is past the window");
        assert_eq!(hits(FaultSite::Publish), 4);
        // other sites keep independent counters and never match this rule
        assert_eq!(poke(FaultSite::Epoch), None);
        assert_eq!(hits(FaultSite::Epoch), 1);
        assert_eq!(poison_index(7), 4 % 7);
    }

    #[test]
    fn injected_error_panics_with_a_downcastable_payload() {
        let _g = FaultPlan::parse("error@drain#1", 0).unwrap().arm();
        let payload = std::panic::catch_unwind(|| poke(FaultSite::Drain))
            .expect_err("the error action must unwind");
        let injected =
            payload.downcast_ref::<InjectedFault>().expect("payload must be InjectedFault");
        assert_eq!(injected.site, "drain");
    }

    #[test]
    fn guard_drop_disarms_and_clears() {
        {
            let _g = FaultPlan::parse("panic@drain#100", 0).unwrap().arm();
            assert!(armed());
            assert_eq!(poke(FaultSite::Drain), None);
            assert_eq!(hits(FaultSite::Drain), 1);
        }
        assert!(!armed());
        assert_eq!(hits(FaultSite::Drain), 0, "the plan (and its counters) are gone");
        assert_eq!(poke(FaultSite::Drain), None);
    }
}
