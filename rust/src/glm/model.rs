//! Trainer state: the dual model `α` and the shared vector `v = Σ α_i x_i`.
//!
//! `v` is the object at the heart of the paper: every coordinate update
//! reads it (to get `⟨x_j, w⟩`) and writes it (rank-1 update `v += δ·x_j`).
//! How it is shared — wildly over one copy, or privately per thread/node
//! with periodic merges — is exactly what distinguishes the solver variants.

use crate::data::{DataMatrix, Dataset};
use crate::glm::Objective;

/// Primal–dual state of an SDCA run.
#[derive(Clone, Debug)]
pub struct ModelState {
    /// Dual variables, one per training example.
    pub alpha: Vec<f64>,
    /// Shared vector `v = Σ_i α_i x_i` (length `d`).
    pub v: Vec<f64>,
}

impl ModelState {
    /// Cold start: `α = 0 ⇒ v = 0` (a dual-feasible point for all three
    /// objectives).
    pub fn zeros(n: usize, d: usize) -> Self {
        ModelState {
            alpha: vec![0.0; n],
            v: vec![0.0; d],
        }
    }

    /// Primal iterate `w = v/(λn)`.
    pub fn w(&self, obj: &Objective) -> Vec<f64> {
        let scale = 1.0 / (obj.lambda() * self.alpha.len() as f64);
        self.v.iter().map(|&vi| vi * scale).collect()
    }

    /// Warm-start seed for a dataset that grew to `n_total` examples: `α`
    /// is extended with zeros for the appended examples (a dual-feasible
    /// point — new examples enter exactly as they would at a cold start)
    /// and `v` is carried over unchanged (`v = Σ α_i x_i` has no term for
    /// a zero-`α` example).
    pub fn extended(&self, n_total: usize) -> ModelState {
        assert!(
            n_total >= self.alpha.len(),
            "extended() cannot shrink the example axis"
        );
        let mut alpha = self.alpha.clone();
        alpha.resize(n_total, 0.0);
        ModelState {
            alpha,
            v: self.v.clone(),
        }
    }

    /// Recompute `v` from scratch (`v = Σ α_i x_i`). Used by the replica
    /// solvers after merges, and by tests to bound drift of the
    /// incrementally-maintained `v`. The sweep walks the (segmented)
    /// matrix through one cursor, so the per-column cost matches the
    /// monolithic layout exactly.
    pub fn rebuild_v<M: DataMatrix>(&mut self, ds: &Dataset<M>) {
        for vi in self.v.iter_mut() {
            *vi = 0.0;
        }
        let mut cur = ds.x.col_cursor();
        for (j, &a) in self.alpha.iter().enumerate() {
            if a != 0.0 {
                cur.axpy(j, a, &mut self.v);
            }
        }
    }

    /// Max |v_incremental − v_rebuilt| — drift diagnostic.
    pub fn v_drift<M: DataMatrix>(&self, ds: &Dataset<M>) -> f64 {
        let mut fresh = vec![0.0; self.v.len()];
        let mut cur = ds.x.col_cursor();
        for (j, &a) in self.alpha.iter().enumerate() {
            if a != 0.0 {
                cur.axpy(j, a, &mut fresh);
            }
        }
        self.v
            .iter()
            .zip(fresh.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

/// Margins `z_j = ⟨x_j, w⟩` for a set of examples (test or train side).
/// Column access goes through a [`ColCursor`](crate::data::ColCursor):
/// request batches are typically windows over one dataset segment, so the
/// chunked storage costs one seat, not one lookup per margin. Bit-wise
/// identical to per-column `dot_col` access (same `dot4_by` reduction on
/// the same slices).
pub fn margins<M: DataMatrix>(ds: &Dataset<M>, w: &[f64], idx: &[usize]) -> Vec<f64> {
    let mut cur = ds.x.col_cursor();
    idx.iter().map(|&j| cur.dot(j, w)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn zeros_is_consistent() {
        let st = ModelState::zeros(5, 3);
        assert_eq!(st.alpha, vec![0.0; 5]);
        assert_eq!(st.v, vec![0.0; 3]);
    }

    #[test]
    fn w_scaling() {
        let obj = Objective::Ridge { lambda: 0.5 };
        let st = ModelState {
            alpha: vec![0.0; 4],
            v: vec![2.0, -4.0],
        };
        let w = st.w(&obj);
        assert_eq!(w, vec![1.0, -2.0]); // v/(0.5·4)
    }

    #[test]
    fn extended_appends_zero_alphas() {
        let st = ModelState {
            alpha: vec![1.0, -2.0],
            v: vec![0.5, 0.25],
        };
        let ext = st.extended(4);
        assert_eq!(ext.alpha, vec![1.0, -2.0, 0.0, 0.0]);
        assert_eq!(ext.v, vec![0.5, 0.25]);
    }

    #[test]
    fn rebuild_matches_incremental() {
        let ds = synthetic::dense_classification(50, 8, 3);
        let mut st = ModelState::zeros(50, 8);
        // apply some updates incrementally
        let mut rng = crate::util::Rng::new(1);
        for _ in 0..200 {
            let j = rng.next_below(50) as usize;
            let delta = rng.next_gaussian() * 0.1;
            st.alpha[j] += delta;
            ds.x.axpy_col(j, delta, &mut st.v);
        }
        assert!(st.v_drift(&ds) < 1e-10);
        let v_inc = st.v.clone();
        st.rebuild_v(&ds);
        for (a, b) in v_inc.iter().zip(st.v.iter()) {
            assert!((a - b).abs() < 1e-10);
        }
    }
}
