//! Primal/dual objective values, duality gap and test metrics.
//!
//! The gap `P(w(α)) − D(α) ≥ 0` certifies solution quality independently of
//! the solver — we use it to verify that every parallel variant reaches the
//! same optimum the sequential algorithm does ("without compromising
//! convergence"), and to detect the wild solver converging to an incorrect
//! fixed point at high thread counts (paper §4, citing PASSCoDe).

use crate::data::{DataMatrix, Dataset};
use crate::glm::{ModelState, Objective};

/// Primal and dual objective values plus their gap.
#[derive(Clone, Copy, Debug)]
pub struct GapReport {
    pub primal: f64,
    pub dual: f64,
    pub gap: f64,
}

/// `P(w) = (1/n) Σ ℓ_i(⟨x_i, w⟩) + (λ/2)‖w‖²`.
pub fn primal_value<M: DataMatrix>(ds: &Dataset<M>, obj: &Objective, w: &[f64]) -> f64 {
    let n = ds.n();
    let mut cur = ds.x.col_cursor();
    let mut loss = 0.0;
    for j in 0..n {
        loss += obj.primal_loss(cur.dot(j, w), ds.y[j]);
    }
    loss / n as f64 + 0.5 * obj.lambda() * crate::util::norm_sq(w)
}

/// `D(α) = −(1/n) Σ ℓ*_i(−α_i) − (λ/2)‖v/(λn)‖²`.
pub fn dual_value<M: DataMatrix>(ds: &Dataset<M>, obj: &Objective, st: &ModelState) -> f64 {
    let n = ds.n();
    let mut conj = 0.0;
    for j in 0..n {
        conj += obj.dual_conjugate(st.alpha[j], ds.y[j]);
    }
    let w = st.w(obj);
    -conj / n as f64 - 0.5 * obj.lambda() * crate::util::norm_sq(&w)
}

/// Full gap report. `O(nnz)` — called once per convergence check, not in
/// the coordinate loop.
pub fn duality_gap<M: DataMatrix>(ds: &Dataset<M>, obj: &Objective, st: &ModelState) -> GapReport {
    let w = st.w(obj);
    let primal = primal_value(ds, obj, &w);
    let dual = dual_value(ds, obj, st);
    GapReport {
        primal,
        dual,
        gap: primal - dual,
    }
}

/// Mean primal loss of `w` on the examples `idx` (the paper's "test loss"
/// axis in Fig. 6 — unregularized mean loss on held-out data).
pub fn test_loss<M: DataMatrix>(ds: &Dataset<M>, obj: &Objective, w: &[f64], idx: &[usize]) -> f64 {
    if idx.is_empty() {
        return 0.0;
    }
    let mut cur = ds.x.col_cursor();
    let mut loss = 0.0;
    for &j in idx {
        loss += obj.primal_loss(cur.dot(j, w), ds.y[j]);
    }
    loss / idx.len() as f64
}

/// Classification accuracy of `w` on the examples `idx`.
pub fn accuracy<M: DataMatrix>(ds: &Dataset<M>, w: &[f64], idx: &[usize]) -> f64 {
    if idx.is_empty() {
        return 0.0;
    }
    let mut cur = ds.x.col_cursor();
    let correct = idx
        .iter()
        .filter(|&&j| cur.dot(j, w) * ds.y[j] > 0.0)
        .count();
    correct as f64 / idx.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn gap_nonnegative_at_zero() {
        let ds = synthetic::dense_classification(100, 10, 1);
        let obj = Objective::Logistic { lambda: 0.01 };
        let st = ModelState::zeros(100, 10);
        let rep = duality_gap(&ds, &obj, &st);
        assert!(rep.gap >= -1e-12, "gap={}", rep.gap);
        // at α=0: P = ln2, D = 0
        assert!((rep.primal - std::f64::consts::LN_2).abs() < 1e-12);
        assert!(rep.dual.abs() < 1e-12);
    }

    #[test]
    fn gap_shrinks_under_coordinate_steps() {
        let ds = synthetic::dense_classification(200, 5, 2);
        let obj = Objective::Logistic { lambda: 0.1 };
        let mut st = ModelState::zeros(200, 5);
        let g0 = duality_gap(&ds, &obj, &st).gap;
        // one exact pass of sequential coordinate ascent
        let n = ds.n();
        let lam_n = obj.lambda() * n as f64;
        for j in 0..n {
            let xw = ds.x.dot_col(j, &st.v) / lam_n;
            let d = obj.delta(st.alpha[j], xw, ds.norm_sq(j), ds.y[j], n);
            st.alpha[j] += d;
            ds.x.axpy_col(j, d, &mut st.v);
        }
        let g1 = duality_gap(&ds, &obj, &st).gap;
        assert!(g1 < g0 * 0.5, "gap should at least halve: {g0} -> {g1}");
        assert!(g1 >= -1e-12);
    }

    #[test]
    fn dual_never_exceeds_primal_random_states() {
        let ds = synthetic::sparse_classification(100, 40, 0.1, 3);
        let obj = Objective::Hinge { lambda: 0.05 };
        let mut rng = crate::util::Rng::new(4);
        for _ in 0..10 {
            let mut st = ModelState::zeros(100, 40);
            for j in 0..100 {
                // dual-feasible hinge point: y·α ∈ [0,1]
                st.alpha[j] = ds.y[j] * rng.next_f64();
            }
            st.rebuild_v(&ds);
            let rep = duality_gap(&ds, &obj, &st);
            assert!(rep.gap >= -1e-10, "weak duality violated: {}", rep.gap);
        }
    }

    #[test]
    fn accuracy_and_test_loss() {
        let ds = synthetic::dense_classification(500, 20, 5);
        let obj = Objective::Logistic { lambda: 1e-3 };
        let idx: Vec<usize> = (0..500).collect();
        let w0 = vec![0.0; 20];
        assert!((test_loss(&ds, &obj, &w0, &idx) - std::f64::consts::LN_2).abs() < 1e-12);
        // a trained-ish w should beat chance (labels are ~linear in x)
        let mut st = ModelState::zeros(500, 20);
        let n = ds.n();
        let lam_n = obj.lambda() * n as f64;
        for _ in 0..3 {
            for j in 0..n {
                let xw = ds.x.dot_col(j, &st.v) / lam_n;
                let d = obj.delta(st.alpha[j], xw, ds.norm_sq(j), ds.y[j], n);
                st.alpha[j] += d;
                ds.x.axpy_col(j, d, &mut st.v);
            }
        }
        let w = st.w(&obj);
        assert!(accuracy(&ds, &w, &idx) > 0.85);
        assert!(test_loss(&ds, &obj, &w, &idx) < 0.5);
    }

    #[test]
    fn empty_index_sets() {
        let ds = synthetic::dense_classification(10, 4, 6);
        let obj = Objective::Logistic { lambda: 1.0 };
        assert_eq!(test_loss(&ds, &obj, &[0.0; 4], &[]), 0.0);
        assert_eq!(accuracy(&ds, &[0.0; 4], &[]), 0.0);
    }
}
