//! Generalized linear models under the SDCA formulation of the paper
//! (Shalev-Shwartz & Zhang 2013, as implemented in Snap ML).
//!
//! Primal problem over `w ∈ R^d`:
//!
//! ```text
//!   min_w  P(w) = (1/n) Σ_i ℓ_i(⟨x_i, w⟩) + (λ/2)‖w‖²
//! ```
//!
//! Dual over `α ∈ R^n`, with the **shared vector** `v = Σ_i α_i x_i` and
//! `w(α) = v / (λn)`:
//!
//! ```text
//!   max_α  D(α) = -(1/n) Σ_i ℓ*_i(-α_i) - (λ/2)‖v/(λn)‖²
//! ```
//!
//! One SDCA step solves the 1-D problem in coordinate `j` exactly
//! (Algorithm 1, line 7): closed form for ridge and hinge, a safeguarded
//! Newton for logistic. `v` is the only cross-coordinate state — it is
//! precisely the vector whose concurrent update the paper's entire systems
//! contribution is about.

pub mod gap;
pub mod model;
pub mod objectives;

pub use gap::{accuracy, duality_gap, primal_value, test_loss, GapReport};
pub use model::ModelState;
pub use objectives::Objective;
