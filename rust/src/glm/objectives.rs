//! Loss functions, their convex conjugates and the exact 1-D dual
//! coordinate solvers used by every SDCA variant in `solver::`.

/// The GLM objective. `lambda` is the L2 regularization strength `λ` of
/// the primal problem `min (1/n)Σℓ + (λ/2)‖w‖²`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Objective {
    /// Logistic regression: `ℓ(z) = log(1 + exp(−y·z))`, `y ∈ {−1,+1}`.
    Logistic { lambda: f64 },
    /// Ridge regression: `ℓ(z) = ½(z − y)²`, real-valued `y`.
    Ridge { lambda: f64 },
    /// L2-regularized SVM (hinge): `ℓ(z) = max(0, 1 − y·z)`, `y ∈ {−1,+1}`.
    Hinge { lambda: f64 },
}

impl Objective {
    #[inline]
    pub fn lambda(&self) -> f64 {
        match *self {
            Objective::Logistic { lambda }
            | Objective::Ridge { lambda }
            | Objective::Hinge { lambda } => lambda,
        }
    }

    /// The same loss family with a different regularization strength (the
    /// serving subsystem's hyperparameter-refit request).
    pub fn with_lambda(&self, lambda: f64) -> Objective {
        match self {
            Objective::Logistic { .. } => Objective::Logistic { lambda },
            Objective::Ridge { .. } => Objective::Ridge { lambda },
            Objective::Hinge { .. } => Objective::Hinge { lambda },
        }
    }

    /// Primal loss `ℓ(z)` at margin/prediction `z` with target `y`.
    #[inline]
    pub fn primal_loss(&self, z: f64, y: f64) -> f64 {
        match self {
            Objective::Logistic { .. } => {
                // numerically-stable log1p(exp(−yz))
                let m = -y * z;
                if m > 35.0 {
                    m
                } else {
                    m.exp().ln_1p()
                }
            }
            Objective::Ridge { .. } => 0.5 * (z - y) * (z - y),
            Objective::Hinge { .. } => (1.0 - y * z).max(0.0),
        }
    }

    /// Conjugate term `ℓ*(-α)` appearing in the dual objective; `+∞`
    /// (represented as a large finite penalty) outside the dual domain.
    #[inline]
    pub fn dual_conjugate(&self, alpha: f64, y: f64) -> f64 {
        match self {
            Objective::Logistic { .. } => {
                // domain: s = y·α ∈ [0, 1]; ℓ*(−α) = s·ln s + (1−s)·ln(1−s)
                let s = y * alpha;
                if !(0.0..=1.0).contains(&s) {
                    return f64::INFINITY;
                }
                let e = |t: f64| if t <= 0.0 { 0.0 } else { t * t.ln() };
                e(s) + e(1.0 - s)
            }
            Objective::Ridge { .. } => 0.5 * alpha * alpha - alpha * y,
            Objective::Hinge { .. } => {
                let s = y * alpha;
                if !(0.0..=1.0).contains(&s) {
                    f64::INFINITY
                } else {
                    -s
                }
            }
        }
    }

    /// Exact solution of the 1-D dual subproblem for coordinate `j`
    /// (Algorithm 1, line 7): returns `δ` such that `α_j ← α_j + δ`.
    ///
    /// * `alpha` — current `α_j`,
    /// * `xw` — `⟨x_j, w⟩ = ⟨x_j, v⟩/(λn)` computed from the (possibly
    ///   stale) shared vector the caller read,
    /// * `norm_sq` — `‖x_j‖²`,
    /// * `y` — target,
    /// * `n` — number of examples (the partition-local `n` for the
    ///   replica-local solvers, following the CoCoA local subproblem).
    #[inline]
    pub fn delta(&self, alpha: f64, xw: f64, norm_sq: f64, y: f64, n: usize) -> f64 {
        if norm_sq <= 0.0 {
            return 0.0;
        }
        let lambda = self.lambda();
        let q = norm_sq / (lambda * n as f64); // curvature of the quadratic term
        match self {
            Objective::Ridge { .. } => (y - alpha - xw) / (1.0 + q),
            Objective::Hinge { .. } => {
                let unc = y * (1.0 - y * xw) / q + alpha; // unconstrained α′ scaled
                let s = (y * unc).clamp(0.0, 1.0);
                y * s - alpha
            }
            Objective::Logistic { .. } => {
                // Solve ln(s/(1−s)) + q·s + c = 0 over s ∈ (0,1) where
                // s = y·(α+δ), c = y·xw − q·y·α. Monotone increasing ⇒
                // unique root; safeguarded Newton (bisection fallback).
                let c = y * xw - q * y * alpha;
                let phi = |s: f64| (s / (1.0 - s)).ln() + q * s + c;
                let (mut lo, mut hi) = (1e-12, 1.0 - 1e-12);
                // root is interior because phi(lo) → −∞, phi(hi) → +∞.
                // Warm start at σ(−c): the exact root when q = 0, and an
                // excellent initial bracket point otherwise — Newton then
                // typically lands in 1–3 iterations (§Perf iteration 2).
                let mut s = (1.0 / (1.0 + c.exp())).clamp(1e-9, 1.0 - 1e-9);
                for _ in 0..50 {
                    let f = phi(s);
                    if f.abs() < 1e-12 {
                        break;
                    }
                    if f > 0.0 {
                        hi = s;
                    } else {
                        lo = s;
                    }
                    let fp = 1.0 / (s * (1.0 - s)) + q;
                    let mut next = s - f / fp;
                    if !(next > lo && next < hi) {
                        next = 0.5 * (lo + hi); // bisection safeguard
                    }
                    if (next - s).abs() < 1e-15 {
                        s = next;
                        break;
                    }
                    s = next;
                }
                y * s - alpha
            }
        }
    }

    /// Derivative of the primal loss wrt `z` — used by the gradient-based
    /// baselines (L-BFGS, SAG, IRLSM).
    #[inline]
    pub fn primal_grad(&self, z: f64, y: f64) -> f64 {
        match self {
            Objective::Logistic { .. } => {
                let m = y * z;
                -y / (1.0 + m.exp())
            }
            Objective::Ridge { .. } => z - y,
            Objective::Hinge { .. } => {
                if y * z < 1.0 {
                    -y
                } else {
                    0.0
                }
            }
        }
    }

    /// Second derivative of the primal loss wrt `z` (IRLSM weights).
    #[inline]
    pub fn primal_hess(&self, z: f64, y: f64) -> f64 {
        match self {
            Objective::Logistic { .. } => {
                let p = 1.0 / (1.0 + (-y * z).exp());
                (p * (1.0 - p)).max(1e-10)
            }
            Objective::Ridge { .. } => 1.0,
            Objective::Hinge { .. } => 0.0, // not twice differentiable
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const OBJS: [Objective; 3] = [
        Objective::Logistic { lambda: 0.1 },
        Objective::Ridge { lambda: 0.1 },
        Objective::Hinge { lambda: 0.1 },
    ];

    /// The defining property of the exact coordinate solver: for the
    /// single-example problem, δ must be a stationary/optimal point of
    /// h(δ) = ℓ*(−(α+δ)) / n + (λ/2)‖w + δ·x/(λn)‖² — we check it by
    /// brute-force sampling of the 1-D objective.
    fn subproblem_value(
        obj: &Objective,
        alpha: f64,
        delta: f64,
        xw: f64,
        nsq: f64,
        y: f64,
        n: usize,
    ) -> f64 {
        let lambda = obj.lambda();
        let a = alpha + delta;
        let conj = obj.dual_conjugate(a, y);
        if !conj.is_finite() {
            return f64::INFINITY;
        }
        // ‖w + δx/(λn)‖² − ‖w‖² = 2δ⟨x,w⟩/(λn) + δ²‖x‖²/(λn)²
        let quad = 2.0 * delta * xw / (lambda * n as f64)
            + delta * delta * nsq / (lambda * lambda * (n * n) as f64);
        conj / n as f64 + 0.5 * lambda * quad
    }

    #[test]
    fn delta_minimizes_subproblem() {
        for obj in OBJS {
            let cases: &[(f64, f64, f64, f64)] = &[
                (0.0, 0.3, 2.0, 1.0),
                (0.2, -1.5, 0.7, 1.0),
                (-0.1, 0.8, 1.3, -1.0),
                (0.5, 2.0, 4.0, -1.0),
            ];
            for &(alpha, xw, nsq, y) in cases {
                // keep α in-domain for constrained losses
                let alpha = match obj {
                    Objective::Logistic { .. } | Objective::Hinge { .. } => {
                        (y * alpha).clamp(0.01, 0.99) * y
                    }
                    _ => alpha,
                };
                let n = 10;
                let d = obj.delta(alpha, xw, nsq, y, n);
                let at_d = subproblem_value(&obj, alpha, d, xw, nsq, y, n);
                assert!(at_d.is_finite(), "{obj:?} produced out-of-domain step");
                for k in -10..=10 {
                    let probe = d + k as f64 * 0.02;
                    let at_p = subproblem_value(&obj, alpha, probe, xw, nsq, y, n);
                    assert!(
                        at_d <= at_p + 1e-8,
                        "{obj:?}: δ={d} not optimal, probe {probe} better ({at_d} > {at_p})"
                    );
                }
            }
        }
    }

    #[test]
    fn ridge_closed_form() {
        let obj = Objective::Ridge { lambda: 0.5 };
        // δ = (y − α − xw)/(1 + q), q = nsq/(λn)
        let d = obj.delta(0.1, 0.2, 2.0, 1.0, 4);
        let q: f64 = 2.0 / (0.5 * 4.0);
        assert!((d - (1.0 - 0.1 - 0.2) / (1.0 + q)).abs() < 1e-12);
    }

    #[test]
    fn hinge_respects_box() {
        let obj = Objective::Hinge { lambda: 0.01 };
        // extremely small q → unconstrained step is huge → clipped to s=1
        let d = obj.delta(0.0, -5.0, 1.0, 1.0, 100);
        assert!((d - 1.0).abs() < 1e-12);
        // opposite direction clips to s=0
        let d2 = obj.delta(1.0, 5.0, 1.0, 1.0, 100);
        assert!((d2 + 1.0).abs() < 1e-12);
    }

    #[test]
    fn logistic_step_stays_in_domain() {
        let obj = Objective::Logistic { lambda: 0.1 };
        for &xw in &[-10.0, -1.0, 0.0, 1.0, 10.0] {
            for &y in &[1.0, -1.0] {
                let d = obj.delta(0.0, xw, 1.0, y, 5);
                let s = y * d;
                assert!(s > 0.0 && s < 1.0, "s={s} out of (0,1) for xw={xw}, y={y}");
            }
        }
    }

    #[test]
    fn logistic_loss_stable_at_extremes() {
        let obj = Objective::Logistic { lambda: 1.0 };
        assert!(obj.primal_loss(100.0, 1.0) < 1e-30);
        assert!((obj.primal_loss(-100.0, 1.0) - 100.0).abs() < 1e-9);
        assert!(obj.primal_loss(0.0, 1.0) - std::f64::consts::LN_2 < 1e-12);
    }

    #[test]
    fn grad_matches_finite_difference() {
        for obj in OBJS {
            let pts: &[(f64, f64)] = &[(0.3, 1.0), (-1.2, -1.0), (2.0, 1.0)];
            for &(z, y) in pts {
                if matches!(obj, Objective::Hinge { .. }) && (1.0 - y * z).abs() < 0.1 {
                    continue; // kink
                }
                let h = 1e-6;
                let fd = (obj.primal_loss(z + h, y) - obj.primal_loss(z - h, y)) / (2.0 * h);
                assert!(
                    (obj.primal_grad(z, y) - fd).abs() < 1e-5,
                    "{obj:?} grad mismatch at z={z}"
                );
            }
        }
    }

    #[test]
    fn hess_matches_finite_difference_logistic() {
        let obj = Objective::Logistic { lambda: 1.0 };
        for &(z, y) in &[(0.0, 1.0), (1.5, -1.0), (-0.7, 1.0)] {
            let h = 1e-5;
            let fd = (obj.primal_grad(z + h, y) - obj.primal_grad(z - h, y)) / (2.0 * h);
            assert!((obj.primal_hess(z, y) - fd).abs() < 1e-5);
        }
    }

    #[test]
    fn conjugate_fenchel_young() {
        // ℓ(z) + ℓ*(−α) ≥ −α·z  (Fenchel–Young, with equality at optimum)
        let obj = Objective::Logistic { lambda: 1.0 };
        for &(z, s, y) in &[(0.5, 0.3, 1.0), (-1.0, 0.7, 1.0), (0.2, 0.5, -1.0)] {
            let alpha = y * s;
            let lhs = obj.primal_loss(z, y) + obj.dual_conjugate(alpha, y);
            assert!(lhs >= -alpha * z - 1e-12);
        }
    }

    #[test]
    fn zero_norm_is_noop() {
        for obj in OBJS {
            assert_eq!(obj.delta(0.3, 1.0, 0.0, 1.0, 10), 0.0);
        }
    }

    #[test]
    fn with_lambda_keeps_loss_family() {
        assert_eq!(
            Objective::Hinge { lambda: 0.1 }.with_lambda(0.2),
            Objective::Hinge { lambda: 0.2 }
        );
        assert_eq!(
            Objective::Logistic { lambda: 1.0 }.with_lambda(0.5).lambda(),
            0.5
        );
    }
}
