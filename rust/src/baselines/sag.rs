//! Stochastic Average Gradient — the algorithm class of scikit-learn's
//! `sag` solver (Schmidt, Le Roux & Bach 2017, as used for
//! `LogisticRegression(solver="sag")`).
//!
//! SAG keeps the most recent loss-gradient *scalar* `g_j = ℓ′(⟨x_j, w⟩)`
//! per example and steps along the average of the remembered gradients:
//! `w ← w(1 − ηλ) − η·(Σ_j g_j x_j)/n`, with the sum maintained
//! incrementally. Step size follows scikit-learn:
//! `η = 1 / (L_max + λ)` with `L_max = ¼ max_j ‖x_j‖²` for logistic
//! (`max ‖x_j‖²` for squared loss).

use super::{BaselineConfig, BaselineOutput};
use crate::data::{DataMatrix, Dataset};
use crate::glm::Objective;
use crate::metrics::{EpochStats, RunRecord};
use crate::util::{Rng, Timer};

pub fn train_sag<M: DataMatrix>(ds: &Dataset<M>, cfg: &BaselineConfig) -> BaselineOutput {
    let n = ds.n();
    let d = ds.d();
    let lambda = cfg.obj.lambda();
    let lip_const = match cfg.obj {
        Objective::Logistic { .. } => 0.25,
        Objective::Ridge { .. } => 1.0,
        Objective::Hinge { .. } => 1.0, // subgradient heuristic
    };
    let l_max = (0..n).map(|j| ds.norm_sq(j)).fold(0.0f64, f64::max) * lip_const;
    let eta = 1.0 / (l_max + lambda).max(1e-12);

    let mut w = vec![0.0f64; d];
    let mut g_mem = vec![0.0f64; n]; // remembered loss-derivative scalars
    let mut g_sum = vec![0.0f64; d]; // Σ g_j·x_j over seen examples
    let mut seen = vec![false; n];
    let mut n_seen = 0usize;
    let mut perm: Vec<u32> = (0..n as u32).collect();
    let mut rng = Rng::new(cfg.seed);

    let total = Timer::start();
    let mut epochs = Vec::new();
    let mut converged = false;
    let mut prev_w = vec![0.0f64; d];
    for epoch in 1..=cfg.max_epochs {
        let t = Timer::start();
        rng.shuffle(&mut perm);
        for &jj in &perm {
            let j = jj as usize;
            let z = ds.x.dot_col(j, &w);
            let g_new = cfg.obj.primal_grad(z, ds.y[j]);
            let g_old = g_mem[j];
            if !seen[j] {
                seen[j] = true;
                n_seen += 1;
            }
            if g_new != g_old {
                ds.x.axpy_col(j, g_new - g_old, &mut g_sum);
                g_mem[j] = g_new;
            }
            // w ← w(1 − ηλ) − (η/m)·g_sum   (m = examples seen so far)
            let shrink = 1.0 - eta * lambda;
            let scale = eta / n_seen as f64;
            for (wi, gi) in w.iter_mut().zip(&g_sum) {
                *wi = *wi * shrink - scale * gi;
            }
        }
        let rel_change = crate::util::rel_change(&w, &prev_w);
        prev_w.copy_from_slice(&w);
        let primal = crate::glm::primal_value(ds, &cfg.obj, &w);
        epochs.push(EpochStats {
            epoch,
            wall_s: t.elapsed_s(),
            rel_change,
            gap: None,
            primal: Some(primal),
        });
        if rel_change < cfg.tol {
            converged = true;
            break;
        }
    }
    let final_primal = crate::glm::primal_value(ds, &cfg.obj, &w);
    BaselineOutput {
        w,
        record: RunRecord {
            solver: "sag".into(),
            threads: 1,
            epochs,
            converged,
            diverged: false,
            total_wall_s: total.elapsed_s(),
        },
        converged,
        final_primal,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn converges_to_sdca_optimum() {
        let ds = synthetic::dense_classification(400, 10, 1);
        let obj = Objective::Logistic { lambda: 1e-2 };
        let sag = train_sag(&ds, &BaselineConfig::new(obj).with_tol(1e-8).with_max_epochs(2000));
        assert!(sag.converged);
        let sdca = crate::solver::seq::train_sequential(
            &ds,
            &crate::solver::SolverConfig::new(obj)
                .with_tol(1e-9)
                .with_max_epochs(2000),
        );
        let dist = crate::util::rel_change(&sag.w, &sdca.weights(&obj));
        assert!(dist < 5e-3, "sag vs sdca: {dist}");
    }

    #[test]
    fn sparse_data_converges() {
        let ds = synthetic::sparse_classification(500, 100, 0.05, 2);
        let obj = Objective::Logistic { lambda: 1.0 / 500.0 };
        let out = train_sag(&ds, &BaselineConfig::new(obj).with_tol(1e-6).with_max_epochs(3000));
        assert!(out.converged);
        // reaches a reasonable objective (close to lbfgs's)
        let lb = super::super::lbfgs::train_lbfgs(&ds, &BaselineConfig::new(obj).with_tol(1e-12));
        assert!(out.final_primal < lb.final_primal + 1e-3);
    }

    #[test]
    fn ridge_converges() {
        let ds = synthetic::dense_regression(300, 6, 0.05, 3);
        let obj = Objective::Ridge { lambda: 0.1 };
        let out = train_sag(&ds, &BaselineConfig::new(obj).with_tol(1e-9).with_max_epochs(3000));
        assert!(out.converged);
    }

    #[test]
    fn objective_eventually_decreases() {
        let ds = synthetic::dense_classification(300, 8, 4);
        let obj = Objective::Logistic { lambda: 1e-2 };
        let out = train_sag(&ds, &BaselineConfig::new(obj).with_max_epochs(50).with_tol(0.0));
        let primals: Vec<f64> = out.record.epochs.iter().filter_map(|e| e.primal).collect();
        assert!(primals.last().unwrap() < &primals[0]);
    }
}
