//! Cyclic dual coordinate descent — the algorithm class behind
//! scikit-learn's `liblinear` backend (Hsieh et al. 2008 for L2-SVM, Yu et
//! al. 2011 for logistic).
//!
//! Same exact 1-D dual solves as `solver::seq`, but with liblinear's
//! *system-oblivious* loop structure, which is exactly what the paper
//! contrasts against: cyclic order with a single random shuffle per outer
//! iteration over **all** example indices (no buckets, no cache-line
//! batching), primal vector maintained directly, stopping on the maximal
//! projected-gradient-style movement within a pass.

use super::{BaselineConfig, BaselineOutput};
use crate::data::{DataMatrix, Dataset};
use crate::metrics::{EpochStats, RunRecord};
use crate::util::{Rng, Timer};

pub fn train_dual_cd<M: DataMatrix>(ds: &Dataset<M>, cfg: &BaselineConfig) -> BaselineOutput {
    let n = ds.n();
    let d = ds.d();
    let lambda = cfg.obj.lambda();
    let inv_lambda_n = 1.0 / (lambda * n as f64);

    let mut alpha = vec![0.0f64; n];
    let mut v = vec![0.0f64; d];
    let mut perm: Vec<u32> = (0..n as u32).collect();
    let mut rng = Rng::new(cfg.seed);

    let total = Timer::start();
    let mut epochs = Vec::new();
    let mut converged = false;
    for epoch in 1..=cfg.max_epochs {
        let t = Timer::start();
        rng.shuffle(&mut perm);
        let mut max_step: f64 = 0.0;
        for &jj in &perm {
            let j = jj as usize;
            let xw = ds.x.dot_col(j, &v) * inv_lambda_n;
            let delta = cfg.obj.delta(alpha[j], xw, ds.norm_sq(j), ds.y[j], n);
            if delta != 0.0 {
                alpha[j] += delta;
                ds.x.axpy_col(j, delta, &mut v);
                max_step = max_step.max(delta.abs());
            }
        }
        epochs.push(EpochStats {
            epoch,
            wall_s: t.elapsed_s(),
            rel_change: max_step,
            gap: None,
            primal: None,
        });
        // liblinear-style: stop when no coordinate moved appreciably
        if max_step < cfg.tol {
            converged = true;
            break;
        }
    }
    let w: Vec<f64> = v.iter().map(|&vi| vi * inv_lambda_n).collect();
    let final_primal = crate::glm::primal_value(ds, &cfg.obj, &w);
    BaselineOutput {
        w,
        record: RunRecord {
            solver: "dual-cd(liblinear)".into(),
            threads: 1,
            epochs,
            converged,
            diverged: false,
            total_wall_s: total.elapsed_s(),
        },
        converged,
        final_primal,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::glm::Objective;

    #[test]
    fn converges_logistic() {
        let ds = synthetic::dense_classification(300, 10, 1);
        let obj = Objective::Logistic { lambda: 1e-2 };
        let out = train_dual_cd(&ds, &BaselineConfig::new(obj).with_tol(1e-8));
        assert!(out.converged);
        let lb = super::super::lbfgs::train_lbfgs(&ds, &BaselineConfig::new(obj).with_tol(1e-12));
        assert!((out.final_primal - lb.final_primal).abs() < 1e-6);
    }

    #[test]
    fn converges_hinge_svm() {
        // liblinear's home turf: L2-regularized SVM
        let ds = synthetic::dense_classification(300, 10, 2);
        let obj = Objective::Hinge { lambda: 1e-2 };
        let out = train_dual_cd(
            &ds,
            &BaselineConfig::new(obj).with_tol(1e-8).with_max_epochs(2000),
        );
        assert!(out.converged);
        let idx: Vec<usize> = (0..300).collect();
        assert!(crate::glm::accuracy(&ds, &out.w, &idx) > 0.85);
    }

    #[test]
    fn sparse_converges() {
        let ds = synthetic::sparse_classification(400, 120, 0.05, 3);
        let obj = Objective::Logistic { lambda: 1.0 / 400.0 };
        let out = train_dual_cd(
            &ds,
            &BaselineConfig::new(obj).with_tol(1e-6).with_max_epochs(1000),
        );
        assert!(out.converged);
    }
}
