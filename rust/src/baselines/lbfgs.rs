//! Limited-memory BFGS with Armijo backtracking — the algorithm class of
//! scikit-learn's `lbfgs` solver (its only multi-threaded one; the
//! BLAS-level parallelism does not change the iteration count, which is
//! what this implementation reproduces).

use super::{BaselineConfig, BaselineOutput};
use crate::data::{DataMatrix, Dataset};
use crate::metrics::{EpochStats, RunRecord};
use crate::util::{dot, Timer};
use std::collections::VecDeque;

/// History depth (scikit-learn default is 10).
const MEMORY: usize = 10;

/// Full-batch primal objective and gradient.
fn objective_grad<M: DataMatrix>(
    ds: &Dataset<M>,
    cfg: &BaselineConfig,
    w: &[f64],
) -> (f64, Vec<f64>) {
    let n = ds.n();
    let lambda = cfg.obj.lambda();
    let mut grad = vec![0.0; ds.d()];
    let mut loss = 0.0;
    for j in 0..n {
        let z = ds.x.dot_col(j, w);
        loss += cfg.obj.primal_loss(z, ds.y[j]);
        let g = cfg.obj.primal_grad(z, ds.y[j]);
        if g != 0.0 {
            ds.x.axpy_col(j, g / n as f64, &mut grad);
        }
    }
    for (gi, wi) in grad.iter_mut().zip(w) {
        *gi += lambda * wi;
    }
    (loss / n as f64 + 0.5 * lambda * crate::util::norm_sq(w), grad)
}

/// Two-loop recursion: `r = H_k · g` from the (s, y) history.
fn two_loop(history: &VecDeque<(Vec<f64>, Vec<f64>)>, g: &[f64]) -> Vec<f64> {
    let mut q = g.to_vec();
    let mut alphas = Vec::with_capacity(history.len());
    for (s, y) in history.iter().rev() {
        let rho = 1.0 / dot(y, s);
        let a = rho * dot(s, &q);
        for (qi, yi) in q.iter_mut().zip(y) {
            *qi -= a * yi;
        }
        alphas.push((a, rho));
    }
    // initial Hessian scaling γ = sᵀy/yᵀy of the most recent pair
    if let Some((s, y)) = history.back() {
        let gamma = dot(s, y) / dot(y, y).max(1e-300);
        for qi in q.iter_mut() {
            *qi *= gamma;
        }
    }
    for ((s, y), (a, rho)) in history.iter().zip(alphas.into_iter().rev()) {
        let b = rho * dot(y, &q);
        for (qi, si) in q.iter_mut().zip(s) {
            *qi += (a - b) * si;
        }
    }
    q
}

pub fn train_lbfgs<M: DataMatrix>(ds: &Dataset<M>, cfg: &BaselineConfig) -> BaselineOutput {
    let d = ds.d();
    let mut w = vec![0.0; d];
    let (mut f, mut g) = objective_grad(ds, cfg, &w);
    let mut history: VecDeque<(Vec<f64>, Vec<f64>)> = VecDeque::with_capacity(MEMORY);

    let total = Timer::start();
    let mut epochs = Vec::new();
    let mut converged = false;
    for epoch in 1..=cfg.max_epochs {
        let t = Timer::start();
        // search direction
        let mut p = two_loop(&history, &g);
        for pi in p.iter_mut() {
            *pi = -*pi;
        }
        let mut gp = dot(&g, &p);
        if gp >= 0.0 {
            // not a descent direction (e.g. empty/stale history): steepest
            p = g.iter().map(|&gi| -gi).collect();
            gp = dot(&g, &p);
        }
        // Armijo backtracking
        let mut step = 1.0;
        let c1 = 1e-4;
        let mut w_new;
        let mut f_new;
        let mut g_new;
        loop {
            w_new = w.iter().zip(&p).map(|(wi, pi)| wi + step * pi).collect::<Vec<_>>();
            let (fv, gv) = objective_grad(ds, cfg, &w_new);
            f_new = fv;
            g_new = gv;
            if f_new <= f + c1 * step * gp || step < 1e-12 {
                break;
            }
            step *= 0.5;
        }
        // curvature pair
        let s: Vec<f64> = w_new.iter().zip(&w).map(|(a, b)| a - b).collect();
        let yv: Vec<f64> = g_new.iter().zip(&g).map(|(a, b)| a - b).collect();
        if dot(&s, &yv) > 1e-12 {
            if history.len() == MEMORY {
                history.pop_front();
            }
            history.push_back((s, yv));
        }
        let rel_impr = (f - f_new).abs() / f.abs().max(1e-12);
        let rel_change = crate::util::rel_change(&w_new, &w);
        w = w_new;
        g = g_new;
        f = f_new;
        epochs.push(EpochStats {
            epoch,
            wall_s: t.elapsed_s(),
            rel_change,
            gap: None,
            primal: Some(f),
        });
        let gnorm = crate::util::norm_sq(&g).sqrt();
        if rel_impr < cfg.tol || gnorm < cfg.tol {
            converged = true;
            break;
        }
    }
    BaselineOutput {
        w,
        record: RunRecord {
            solver: "lbfgs".into(),
            threads: 1,
            epochs,
            converged,
            diverged: false,
            total_wall_s: total.elapsed_s(),
        },
        converged,
        final_primal: f,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::glm::Objective;

    #[test]
    fn converges_on_logistic() {
        let ds = synthetic::dense_classification(400, 15, 1);
        let cfg = BaselineConfig::new(Objective::Logistic { lambda: 1e-2 }).with_tol(1e-10);
        let out = train_lbfgs(&ds, &cfg);
        assert!(out.converged);
        // stationarity
        let (_, g) = objective_grad(&ds, &cfg, &out.w);
        assert!(crate::util::norm_sq(&g).sqrt() < 1e-5);
    }

    #[test]
    fn matches_sdca_optimum() {
        let ds = synthetic::dense_classification(300, 10, 2);
        let obj = Objective::Logistic { lambda: 1e-2 };
        let lb = train_lbfgs(&ds, &BaselineConfig::new(obj).with_tol(1e-12));
        let sdca = crate::solver::seq::train_sequential(
            &ds,
            &crate::solver::SolverConfig::new(obj)
                .with_tol(1e-9)
                .with_max_epochs(2000),
        );
        let dist = crate::util::rel_change(&lb.w, &sdca.weights(&obj));
        assert!(dist < 1e-3, "lbfgs vs sdca: {dist}");
    }

    #[test]
    fn works_on_ridge_and_sparse() {
        let ds = synthetic::sparse_classification(300, 80, 0.1, 3);
        let cfg = BaselineConfig::new(Objective::Logistic { lambda: 1e-2 });
        let out = train_lbfgs(&ds, &cfg);
        assert!(out.converged);

        let dsr = synthetic::dense_regression(200, 8, 0.1, 4);
        let cfgr = BaselineConfig::new(Objective::Ridge { lambda: 0.1 });
        let outr = train_lbfgs(&dsr, &cfgr);
        assert!(outr.converged);
    }

    #[test]
    fn objective_monotone_nonincreasing() {
        let ds = synthetic::dense_classification(200, 12, 5);
        let cfg = BaselineConfig::new(Objective::Logistic { lambda: 1e-3 }).with_max_epochs(30);
        let out = train_lbfgs(&ds, &cfg);
        let primals: Vec<f64> = out.record.epochs.iter().filter_map(|e| e.primal).collect();
        for pair in primals.windows(2) {
            assert!(pair[1] <= pair[0] + 1e-12, "objective increased: {pair:?}");
        }
    }
}
