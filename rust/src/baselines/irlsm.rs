//! IRLSM — iteratively reweighted least squares (exact Newton for GLMs),
//! the core of H2O's default GLM solver.
//!
//! Each outer iteration forms the weighted normal equations
//! `(XᵀWX/n + λI)·Δ = −∇P(w)` with `W = diag(ℓ″(z_j))` and solves them by
//! Cholesky — O(n·d² + d³) per iteration, a handful of iterations to
//! machine precision on narrow data, hopeless on wide data (which is why
//! H2O's `auto` switches to L-BFGS there, mirrored in
//! [`super::h2o_auto`]). A step-halving line search guards the Newton
//! step, as H2O does.

use super::{BaselineConfig, BaselineOutput};
use crate::data::{DataMatrix, Dataset};
use crate::metrics::{EpochStats, RunRecord};
use crate::util::linalg::SymMatrix;
use crate::util::Timer;

pub fn train_irlsm<M: DataMatrix>(ds: &Dataset<M>, cfg: &BaselineConfig) -> BaselineOutput {
    let n = ds.n();
    let d = ds.d();
    let lambda = cfg.obj.lambda();
    let mut w = vec![0.0f64; d];
    let mut f = crate::glm::primal_value(ds, &cfg.obj, &w);
    let mut col_buf = vec![0.0f64; d];

    let total = Timer::start();
    let mut epochs = Vec::new();
    let mut converged = false;
    for epoch in 1..=cfg.max_epochs {
        let t = Timer::start();
        // assemble gradient and weighted Gram matrix
        let mut grad = vec![0.0f64; d];
        let mut hess = SymMatrix::zeros(d);
        for j in 0..n {
            let z = ds.x.dot_col(j, &w);
            let g = cfg.obj.primal_grad(z, ds.y[j]);
            let h = cfg.obj.primal_hess(z, ds.y[j]);
            if g != 0.0 {
                ds.x.axpy_col(j, g / n as f64, &mut grad);
            }
            if h != 0.0 {
                ds.x.write_col_dense(j, &mut col_buf);
                hess.rank1(h / n as f64, &col_buf);
            }
        }
        for (gi, wi) in grad.iter_mut().zip(&w) {
            *gi += lambda * wi;
        }
        hess.add_diag(lambda.max(1e-10));
        // Newton direction: H·p = −grad
        let neg: Vec<f64> = grad.iter().map(|g| -g).collect();
        let p = match crate::util::linalg::spd_solve(hess, &neg) {
            Ok(p) => p,
            Err(_) => neg, // fall back to steepest descent
        };
        // step-halving line search
        let mut step = 1.0f64;
        let mut w_new = w.clone();
        let mut f_new = f;
        for _ in 0..40 {
            for ((wn, wi), pi) in w_new.iter_mut().zip(&w).zip(&p) {
                *wn = wi + step * pi;
            }
            f_new = crate::glm::primal_value(ds, &cfg.obj, &w_new);
            if f_new <= f {
                break;
            }
            step *= 0.5;
        }
        let rel_change = crate::util::rel_change(&w_new, &w);
        let rel_impr = (f - f_new).abs() / f.abs().max(1e-12);
        w = w_new;
        f = f_new;
        epochs.push(EpochStats {
            epoch,
            wall_s: t.elapsed_s(),
            rel_change,
            gap: None,
            primal: Some(f),
        });
        if rel_impr < cfg.tol || rel_change < cfg.tol {
            converged = true;
            break;
        }
    }
    BaselineOutput {
        w,
        record: RunRecord {
            solver: "irlsm(h2o)".into(),
            threads: 1,
            epochs,
            converged,
            diverged: false,
            total_wall_s: total.elapsed_s(),
        },
        converged,
        final_primal: f,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::glm::Objective;

    #[test]
    fn newton_converges_in_few_iterations() {
        let ds = synthetic::dense_classification(500, 12, 1);
        let obj = Objective::Logistic { lambda: 1e-2 };
        let out = train_irlsm(&ds, &BaselineConfig::new(obj).with_tol(1e-10));
        assert!(out.converged);
        assert!(
            out.record.epochs_run() <= 15,
            "Newton should converge fast, took {}",
            out.record.epochs_run()
        );
        let lb = super::super::lbfgs::train_lbfgs(&ds, &BaselineConfig::new(obj).with_tol(1e-12));
        assert!((out.final_primal - lb.final_primal).abs() < 1e-8);
    }

    #[test]
    fn ridge_is_one_newton_step() {
        // quadratic objective ⇒ a single exact Newton step reaches optimum
        let ds = synthetic::dense_regression(200, 6, 0.05, 2);
        let obj = Objective::Ridge { lambda: 0.1 };
        let out = train_irlsm(&ds, &BaselineConfig::new(obj).with_tol(1e-12));
        assert!(out.record.epochs_run() <= 3, "{}", out.record.epochs_run());
    }

    #[test]
    fn sparse_data_works() {
        let ds = synthetic::sparse_classification(300, 60, 0.1, 3);
        let obj = Objective::Logistic { lambda: 1e-2 };
        let out = train_irlsm(&ds, &BaselineConfig::new(obj).with_tol(1e-9));
        assert!(out.converged);
    }
}
