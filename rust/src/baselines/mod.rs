//! Comparator solvers for the Fig. 6 study.
//!
//! The paper benchmarks Snap ML against scikit-learn (`liblinear`,
//! `lbfgs`, `sag`) and H2O's `auto` solver. We reimplement each *algorithm
//! class* from scratch on the same data path, so the comparison measures
//! algorithms rather than framework plumbing:
//!
//! | paper comparator        | module        | algorithm |
//! |-------------------------|---------------|-----------|
//! | scikit-learn liblinear  | [`dual_cd`]   | cyclic dual coordinate descent |
//! | scikit-learn lbfgs      | [`lbfgs`]     | limited-memory BFGS + Armijo   |
//! | scikit-learn sag        | [`sag`]       | stochastic average gradient    |
//! | H2O auto                | [`irlsm`]     | IRLSM (Newton / weighted LS), falling back to L-BFGS for wide data — H2O's documented policy |
//!
//! All solve the same primal `min (1/n)Σℓ + (λ/2)‖w‖²` as `solver::`, so
//! duality-gap/test-loss numbers are directly comparable.

pub mod dual_cd;
pub mod irlsm;
pub mod lbfgs;
pub mod sag;

use crate::data::{DataMatrix, Dataset};
use crate::glm::Objective;
use crate::metrics::RunRecord;

/// Result of a baseline (primal) solver run.
pub struct BaselineOutput {
    /// Learned primal weights.
    pub w: Vec<f64>,
    pub record: RunRecord,
    pub converged: bool,
    /// Final primal objective value.
    pub final_primal: f64,
}

/// Common stopping configuration for the baselines.
#[derive(Clone, Debug)]
pub struct BaselineConfig {
    pub obj: Objective,
    pub max_epochs: usize,
    /// Stop when the primal objective improves by less than `tol`
    /// relatively between passes (scikit-learn-style criterion).
    pub tol: f64,
    pub seed: u64,
}

impl BaselineConfig {
    pub fn new(obj: Objective) -> Self {
        BaselineConfig {
            obj,
            max_epochs: 500,
            tol: 1e-6,
            seed: 42,
        }
    }

    pub fn with_tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }

    pub fn with_max_epochs(mut self, e: usize) -> Self {
        self.max_epochs = e;
        self
    }
}

/// H2O's `auto` policy for GLMs: IRLSM when the problem is narrow enough
/// for the normal equations, L-BFGS for wide data.
pub fn h2o_auto<M: DataMatrix>(ds: &Dataset<M>, cfg: &BaselineConfig) -> BaselineOutput {
    const IRLSM_MAX_D: usize = 600; // H2O switches around O(500) predictors
    if ds.d() <= IRLSM_MAX_D {
        irlsm::train_irlsm(ds, cfg)
    } else {
        let mut out = lbfgs::train_lbfgs(ds, cfg);
        out.record.solver = format!("h2o-auto[{}]", out.record.solver);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn h2o_auto_picks_by_width() {
        let narrow = synthetic::dense_classification(200, 10, 1);
        let cfg = BaselineConfig::new(Objective::Logistic { lambda: 0.01 }).with_max_epochs(50);
        let out = h2o_auto(&narrow, &cfg);
        assert!(out.record.solver.contains("irlsm"), "{}", out.record.solver);
        let wide = synthetic::dense_classification(50, 700, 2);
        let out = h2o_auto(&wide, &cfg);
        assert!(
            out.record.solver.contains("h2o-auto[lbfgs"),
            "{}",
            out.record.solver
        );
    }
}
