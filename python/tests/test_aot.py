"""AOT pipeline tests: lowering produces loadable HLO text + manifest."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot, model


def test_lower_every_artifact_produces_hlo_text():
    for name in model.ARTIFACTS:
        text, entry = aot.lower_artifact(name)
        assert "HloModule" in text, f"{name}: not HLO text"
        assert "ROOT" in text, f"{name}: no root instruction"
        assert entry["inputs"], name
        assert entry["outputs"], name


def test_hlo_text_has_no_custom_calls():
    """interpret=True Pallas must lower to plain HLO — a Mosaic custom-call
    would be unexecutable on the CPU PJRT plugin the rust runtime uses."""
    for name in model.ARTIFACTS:
        text, _ = aot.lower_artifact(name)
        assert "custom-call" not in text, f"{name} contains a custom-call"


def test_manifest_shapes_match_registry():
    text, entry = aot.lower_artifact("eval_tile")
    assert entry["inputs"][0]["shape"] == [256, 128]
    assert entry["outputs"][0]["shape"] == [3]
    assert all(i["dtype"] == "float32" for i in entry["inputs"])


def test_cli_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out), "--only", "loss_tile"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    assert (out / "loss_tile.hlo.txt").exists()
    manifest = json.loads((out / "manifest.json").read_text())
    assert "loss_tile" in manifest


@pytest.mark.parametrize("name", list(model.ARTIFACTS))
def test_lowered_parameter_count_matches_manifest(name):
    text, entry = aot.lower_artifact(name)
    # each input appears as parameter(k) in the entry computation
    for k in range(len(entry["inputs"])):
        assert f"parameter({k})" in text, f"{name}: missing parameter({k})"
