"""Layer-2 model graph tests: shapes, composition and gradient math."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import sdca_kernels as k

jax.config.update("jax_enable_x64", False)


def _tile(seed, m=k.TILE_M, d=k.TILE_D):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, d)).astype(np.float32) / np.sqrt(d)
    y = np.where(rng.random(m) < 0.5, -1.0, 1.0).astype(np.float32)
    mask = np.ones(m, np.float32)
    w = rng.standard_normal(d).astype(np.float32) * 0.3
    return x, y, mask, w


def test_eval_tile_matches_direct():
    x, y, mask, w = _tile(0)
    (got,) = model.eval_tile(*(jnp.asarray(a) for a in (x, y, mask, w)))
    z = x @ w
    loss = np.log1p(np.exp(-(y * z))).sum()
    correct = float(((z * y) > 0).sum())
    np.testing.assert_allclose(np.asarray(got), [loss, correct, float(len(y))], rtol=1e-3)


def test_matvec_plus_loss_composes_to_eval():
    """Feature-tiled path (matvec per tile + loss_tile) must equal the fused
    eval_tile — this is the composition the rust runtime performs for
    d > TILE_D datasets."""
    x, y, mask, w = _tile(1)
    half = k.TILE_D // 2
    (z1,) = model.matvec_tile(jnp.asarray(np.pad(x[:, :half], ((0, 0), (0, half)))), jnp.asarray(np.pad(w[:half], (0, half))))
    (z2,) = model.matvec_tile(jnp.asarray(np.pad(x[:, half:], ((0, 0), (0, half)))), jnp.asarray(np.pad(w[half:], (0, half))))
    (split,) = model.loss_tile(z1 + z2, jnp.asarray(y), jnp.asarray(mask))
    (fused,) = model.eval_tile(*(jnp.asarray(a) for a in (x, y, mask, w)))
    np.testing.assert_allclose(np.asarray(split), np.asarray(fused), rtol=1e-4, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_grad_tile_matches_autodiff(seed):
    x, y, mask, w = _tile(seed, m=k.TILE_M)

    def loss_fn(w_):
        z = jnp.asarray(x) @ w_
        return jnp.sum(jnp.log1p(jnp.exp(-jnp.asarray(y) * z)) * jnp.asarray(mask))

    want = jax.grad(loss_fn)(jnp.asarray(w))
    got, loss = model.grad_tile(*(jnp.asarray(a) for a in (x, y, mask, w)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(float(loss), float(loss_fn(jnp.asarray(w))), rtol=1e-4)


def test_grad_tile_masked_rows_contribute_zero():
    x, y, mask, w = _tile(3)
    mask2 = mask.copy()
    mask2[10:] = 0.0
    g_full, _ = model.grad_tile(*(jnp.asarray(a) for a in (x, y, mask2, w)))
    g_manual, _ = model.grad_tile(
        jnp.asarray(np.concatenate([x[:10], np.zeros_like(x[10:])])),
        jnp.asarray(y),
        jnp.asarray(mask2),
        jnp.asarray(w),
    )
    np.testing.assert_allclose(np.asarray(g_full), np.asarray(g_manual), atol=1e-4)


def test_artifact_registry_shapes_lower():
    """Every registered artifact must trace at its example shapes."""
    for name, (fn, example) in model.ARTIFACTS.items():
        out = jax.eval_shape(fn, *example())
        leaves = jax.tree_util.tree_leaves(out)
        assert leaves, f"{name} produced no outputs"
        for leaf in leaves:
            assert all(dim > 0 for dim in leaf.shape) or leaf.shape == (), name
