"""Kernel-vs-oracle correctness: the core signal of the compile path.

Each Pallas kernel is checked against its independent pure-numpy oracle in
:mod:`compile.kernels.ref`, with hypothesis sweeping shapes, dtypes and
value ranges.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, sdca_kernels as k

jax.config.update("jax_enable_x64", False)


def rand(rng, *shape, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(np.float32)


# ---------------------------------------------------------------- matvec


@settings(max_examples=25, deadline=None)
@given(
    m_blocks=st.integers(1, 4),
    d=st.sampled_from([8, 64, 128, 256]),
    block_m=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matvec_matches_ref(m_blocks, d, block_m, seed):
    rng = np.random.default_rng(seed)
    m = m_blocks * block_m
    x = rand(rng, m, d)
    w = rand(rng, d)
    got = k.matvec(jnp.asarray(x), jnp.asarray(w), block_m=block_m)
    want = ref.matvec_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_matvec_canonical_tile():
    rng = np.random.default_rng(0)
    x = rand(rng, k.TILE_M, k.TILE_D)
    w = rand(rng, k.TILE_D)
    got = k.matvec(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), x @ w, rtol=1e-5, atol=1e-5)


def test_matvec_rejects_ragged():
    x = jnp.zeros((100, 16), jnp.float32)  # 100 not divisible by 256
    with pytest.raises(AssertionError):
        k.matvec(x, jnp.zeros((16,), jnp.float32))


# ------------------------------------------------------- logloss_metrics


@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([8, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
    pad=st.integers(0, 7),
)
def test_logloss_matches_ref(m, seed, pad):
    rng = np.random.default_rng(seed)
    z = rand(rng, m, scale=3.0)
    y = np.where(rng.random(m) < 0.5, -1.0, 1.0).astype(np.float32)
    mask = np.ones(m, np.float32)
    if pad:
        mask[-min(pad, m - 1):] = 0.0
    got = np.asarray(k.logloss_metrics(jnp.asarray(z), jnp.asarray(y), jnp.asarray(mask)))
    want = ref.logloss_metrics_ref(z, y, mask)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_logloss_extreme_margins_stable():
    z = jnp.asarray([100.0, -100.0, 0.0], jnp.float32)
    y = jnp.asarray([1.0, 1.0, 1.0], jnp.float32)
    mask = jnp.ones(3, jnp.float32)
    got = np.asarray(k.logloss_metrics(z, y, mask))
    assert np.isfinite(got).all()
    # loss ≈ 0 + 100 + ln2
    np.testing.assert_allclose(got[0], 100.0 + np.log(2.0), rtol=1e-4)
    assert got[1] == 1.0  # only the first is correct (z=0 counts incorrect)
    assert got[2] == 3.0


def test_logloss_all_masked():
    z = jnp.ones(8, jnp.float32)
    y = jnp.ones(8, jnp.float32)
    got = np.asarray(k.logloss_metrics(z, y, jnp.zeros(8, jnp.float32)))
    np.testing.assert_allclose(got, [0.0, 0.0, 0.0])


# -------------------------------------------------------- bucket_sdca


def make_bucket(rng, b=8, d=32, lam=0.01, n=1000, sigma=1.0, alpha0=None):
    x = rand(rng, b, d, scale=1.0 / np.sqrt(d))
    y = np.where(rng.random(b) < 0.5, -1.0, 1.0).astype(np.float32)
    alpha = alpha0 if alpha0 is not None else (y * rng.random(b) * 0.5).astype(np.float32)
    nsq = (x * x).sum(axis=1).astype(np.float32)
    v = rand(rng, d, scale=0.1)
    inv_lambda_n = 1.0 / (lam * n)
    n_eff = n / sigma
    scalars = np.array([inv_lambda_n, n_eff, sigma, n], np.float32)
    return x, y, alpha, nsq, v, scalars


@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 4, 8]),
    d=st.sampled_from([8, 32, 128]),
    sigma=st.sampled_from([1.0, 4.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_bucket_step_matches_ref(b, d, sigma, seed):
    rng = np.random.default_rng(seed)
    args = make_bucket(rng, b=b, d=d, sigma=sigma)
    a_got, v_got = k.bucket_sdca_step(*[jnp.asarray(a) for a in args])
    a_want, v_want = ref.bucket_sdca_step_ref(*args)
    np.testing.assert_allclose(np.asarray(a_got), a_want, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(v_got), v_want, rtol=2e-3, atol=2e-3)


def test_bucket_step_from_zero_alpha():
    rng = np.random.default_rng(7)
    args = make_bucket(rng, alpha0=np.zeros(8, np.float32))
    a_got, v_got = k.bucket_sdca_step(*[jnp.asarray(a) for a in args])
    a_want, v_want = ref.bucket_sdca_step_ref(*args)
    np.testing.assert_allclose(np.asarray(a_got), a_want, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(v_got), v_want, rtol=2e-3, atol=2e-3)
    # logistic duals must stay in the domain y·α ∈ (0,1)
    s = np.asarray(a_got) * args[1]
    assert ((s > 0) & (s < 1)).all()


def test_bucket_step_zero_norm_rows_noop():
    rng = np.random.default_rng(9)
    x, y, alpha, nsq, v, scalars = make_bucket(rng)
    x[3] = 0.0
    nsq = (x * x).sum(axis=1).astype(np.float32)
    a_got, _ = k.bucket_sdca_step(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(alpha), jnp.asarray(nsq),
        jnp.asarray(v), jnp.asarray(scalars),
    )
    assert np.asarray(a_got)[3] == alpha[3]


def test_bucket_step_improves_local_dual():
    """After the bucket pass, re-running it should produce (near-)zero
    further movement when v is held by the same σ-scaled view — i.e. the
    kernel solves each 1-D problem to optimality."""
    rng = np.random.default_rng(11)
    args = make_bucket(rng, b=4, d=16)
    a1, v1 = k.bucket_sdca_step(*[jnp.asarray(a) for a in args])
    # feed the outputs back in (same bucket, updated state)
    x, y, _, nsq, _, scalars = args
    a2, _ = k.bucket_sdca_step(
        jnp.asarray(x), jnp.asarray(y), a1, jnp.asarray(nsq), v1, jnp.asarray(scalars)
    )
    # second pass deltas are much smaller than first pass deltas
    d1 = np.abs(np.asarray(a1) - args[2]).max()
    d2 = np.abs(np.asarray(a2) - np.asarray(a1)).max()
    assert d2 < 0.5 * d1 + 1e-4, (d1, d2)


# ------------------------------------------------------------ vmem


def test_vmem_estimate_fits_tpu_budget():
    # canonical tile must fit a ~16 MiB VMEM with generous headroom
    assert k.vmem_bytes_estimate() < 1 << 20
