"""Layer-1 Pallas kernels for the SDCA training system.

These are the dense bulk-compute hot-spots the rust coordinator offloads to
AOT-compiled XLA executables:

* :func:`matvec` — tiled margins ``z = X @ w`` (the inner-product engine of
  loss/gradient evaluation),
* :func:`logloss_metrics` — fused logistic-loss + accuracy reduction,
* :func:`bucket_sdca_step` — one *bucket* of exact SDCA coordinate updates
  (the paper's cache-line bucket, re-thought as a VMEM tile).

Hardware adaptation (DESIGN.md §3): the paper's CPU insight is "coarsen the
random access granularity to the memory system's native tile". On TPU the
native tile is the VMEM block: ``BlockSpec`` below expresses the HBM→VMEM
schedule the paper implemented with cache lines and prefetching.

All kernels run ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowers to plain HLO that the rust
runtime executes anywhere. Real-TPU efficiency is estimated analytically in
EXPERIMENTS.md §Perf from the chosen block shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Canonical AOT tile shapes (MXU-aligned: multiples of 8 sublanes × 128
# lanes). The rust runtime pads every dataset tile to these.
TILE_M = 256  # examples per evaluation tile
TILE_D = 128  # features per tile
BUCKET_B = 8  # examples per SDCA bucket (64B line / 8B per α entry)


def _matvec_kernel(x_ref, w_ref, o_ref):
    """One grid step: o = X_block @ w  (X_block: (bm, D) in VMEM)."""
    o_ref[...] = x_ref[...] @ w_ref[...]


def matvec(x: jax.Array, w: jax.Array, block_m: int = TILE_M) -> jax.Array:
    """Tiled margins ``z = X @ w`` over a (M, D) example tile.

    The grid walks the M dimension in ``block_m`` rows; each step streams
    one (block_m, D) block HBM→VMEM while ``w`` stays resident — the TPU
    analogue of the paper's sequential column streaming + model-vector
    reuse.
    """
    m, d = x.shape
    assert m % block_m == 0, f"M={m} must be a multiple of block_m={block_m}"
    grid = (m // block_m,)
    return pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), x.dtype),
        interpret=True,
    )(x, w)


def _logloss_kernel(z_ref, y_ref, mask_ref, o_ref):
    """Fused logistic-loss + correct-count + mask-count reduction."""
    z = z_ref[...]
    y = y_ref[...]
    m = mask_ref[...]
    margin = -y * z
    # numerically-stable log1p(exp(margin))
    loss = jnp.where(margin > 30.0, margin, jnp.log1p(jnp.exp(jnp.minimum(margin, 30.0))))
    correct = jnp.where(z * y > 0.0, 1.0, 0.0)
    o_ref[0] = jnp.sum(loss * m)
    o_ref[1] = jnp.sum(correct * m)
    o_ref[2] = jnp.sum(m)


def logloss_metrics(z: jax.Array, y: jax.Array, mask: jax.Array) -> jax.Array:
    """``[Σ mask·ℓ(z,y), Σ mask·1[correct], Σ mask]`` for a margin tile.

    ``mask`` zeroes the padding rows the rust runtime adds to fill the last
    tile of a dataset.
    """
    (m,) = z.shape
    return pl.pallas_call(
        _logloss_kernel,
        out_shape=jax.ShapeDtypeStruct((3,), z.dtype),
        interpret=True,
    )(z, y, mask)


def _newton_logistic(s0, q, c, iters: int = 30):
    """Safeguarded Newton for φ(s) = ln(s/(1−s)) + q·s + c = 0 on (0,1).

    φ is strictly increasing, so the root is unique; we carry a bisection
    bracket and fall back to its midpoint whenever the Newton step leaves
    the bracket. Fixed iteration count (no data-dependent control flow) so
    the lowering stays a straight-line HLO loop.
    """
    eps = 1e-6

    def body(_, carry):
        s, lo, hi = carry
        f = jnp.log(s / (1.0 - s)) + q * s + c
        lo = jnp.where(f > 0.0, lo, s)
        hi = jnp.where(f > 0.0, s, hi)
        fp = 1.0 / (s * (1.0 - s)) + q
        nxt = s - f / fp
        good = (nxt > lo) & (nxt < hi)
        nxt = jnp.where(good, nxt, 0.5 * (lo + hi))
        return nxt, lo, hi

    s, _, _ = jax.lax.fori_loop(0, iters, body, (jnp.clip(s0, eps, 1.0 - eps), eps, 1.0 - eps))
    return s


def _bucket_kernel(x_ref, y_ref, a_ref, nsq_ref, v_ref, scal_ref, a_out, v_out):
    """Sequential exact SDCA steps over one bucket, entirely in VMEM.

    scal_ref packs ``[inv_lambda_n, n_eff, sigma]`` (see
    ``solver::dom::worker_round`` on the rust side for the σ′ algebra).
    """
    xs = x_ref[...]  # (B, D) — the whole bucket tile lives in VMEM
    ys = y_ref[...]
    nsq = nsq_ref[...]
    inv_lambda_n = scal_ref[0]
    n_eff = scal_ref[1]
    sigma = scal_ref[2]
    b = xs.shape[0]

    def step(i, carry):
        alpha, v = carry
        x = jax.lax.dynamic_index_in_dim(xs, i, axis=0, keepdims=False)
        y = jax.lax.dynamic_index_in_dim(ys, i, axis=0, keepdims=False)
        a = jax.lax.dynamic_index_in_dim(alpha, i, axis=0, keepdims=False)
        ns = jax.lax.dynamic_index_in_dim(nsq, i, axis=0, keepdims=False)
        xw = jnp.dot(x, v) * inv_lambda_n
        # q = ‖x‖²/(λ·n_eff) = ‖x‖²·inv_lambda_n·(n/n_eff)
        q = ns * inv_lambda_n * (scal_ref[3] / jnp.maximum(n_eff, 1.0))
        c = y * xw - q * y * a
        s = _newton_logistic(y * a, q, c)
        delta = jnp.where(ns > 0.0, y * s - a, 0.0)
        alpha = jax.lax.dynamic_update_index_in_dim(alpha, a + delta, i, axis=0)
        v = v + sigma * delta * x
        return alpha, v

    alpha0 = a_ref[...]
    v0 = v_ref[...]
    alpha1, v1 = jax.lax.fori_loop(0, b, step, (alpha0, v0))
    a_out[...] = alpha1
    v_out[...] = v1


def bucket_sdca_step(
    x: jax.Array,
    y: jax.Array,
    alpha: jax.Array,
    nsq: jax.Array,
    v: jax.Array,
    scalars: jax.Array,
):
    """One bucket of exact logistic-SDCA coordinate updates.

    Args:
      x: (B, D) bucket of dense examples.
      y: (B,) labels in {−1, +1}.
      alpha: (B,) current dual coordinates of the bucket.
      nsq: (B,) cached ‖x_j‖².
      v: (D,) the worker's replica of the shared vector (σ′-scaled view).
      scalars: (4,) = [inv_lambda_n, n_eff, sigma, n] packed run constants.

    Returns:
      (alpha', v'): updated bucket duals and replica.
    """
    b, d = x.shape
    return pl.pallas_call(
        _bucket_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((b,), x.dtype),
            jax.ShapeDtypeStruct((d,), x.dtype),
        ),
        interpret=True,
    )(x, y, alpha, nsq, v, scalars)


@functools.lru_cache(maxsize=None)
def vmem_bytes_estimate(block_m: int = TILE_M, d: int = TILE_D, dtype_bytes: int = 4) -> int:
    """Analytic VMEM footprint of one matvec grid step (DESIGN.md §Perf):
    X block + w + z block, double-buffered X stream."""
    x_block = block_m * d * dtype_bytes
    return 2 * x_block + d * dtype_bytes + block_m * dtype_bytes
