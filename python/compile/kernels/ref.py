"""Pure-jnp oracles for the Pallas kernels.

Independent implementations of the same math (no Pallas, no shared helper
code on the numerics) — pytest asserts ``allclose`` between each kernel and
its oracle across shapes and dtypes. This is the core correctness signal of
the compile path; the rust test-suite separately validates the loaded HLO
artifacts against the rust-native f64 implementations.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def matvec_ref(x, w):
    """z = X @ w."""
    return jnp.asarray(x) @ jnp.asarray(w)


def logloss_metrics_ref(z, y, mask):
    """[Σ mask·log(1+e^{−yz}), Σ mask·1[yz>0], Σ mask] — stable log1p."""
    z = np.asarray(z, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    m = np.asarray(mask, dtype=np.float64)
    margin = -y * z
    loss = np.where(margin > 30.0, margin, np.log1p(np.exp(np.minimum(margin, 30.0))))
    correct = (z * y > 0).astype(np.float64)
    return np.array([np.sum(loss * m), np.sum(correct * m), np.sum(m)])


def _solve_logistic_1d(s0, q, c, iters=200):
    """Bisection-only root of ln(s/(1−s)) + q·s + c = 0 (oracle solver —
    deliberately a different algorithm than the kernel's Newton)."""
    lo, hi = 1e-9, 1.0 - 1e-9
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        f = np.log(mid / (1.0 - mid)) + q * mid + c
        if f > 0:
            hi = mid
        else:
            lo = mid
    return 0.5 * (lo + hi)


def bucket_sdca_step_ref(x, y, alpha, nsq, v, scalars):
    """Plain-python sequential SDCA over the bucket (float64)."""
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    alpha = np.asarray(alpha, dtype=np.float64).copy()
    nsq = np.asarray(nsq, dtype=np.float64)
    v = np.asarray(v, dtype=np.float64).copy()
    inv_lambda_n, n_eff, sigma, n = [float(s) for s in np.asarray(scalars)]
    b = x.shape[0]
    for i in range(b):
        if nsq[i] <= 0.0:
            continue
        xw = float(x[i] @ v) * inv_lambda_n
        q = nsq[i] * inv_lambda_n * (n / max(n_eff, 1.0))
        c = y[i] * xw - q * y[i] * alpha[i]
        s = _solve_logistic_1d(y[i] * alpha[i], q, c)
        delta = y[i] * s - alpha[i]
        alpha[i] += delta
        v += sigma * delta * x[i]
    return alpha, v
