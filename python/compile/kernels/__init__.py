"""Layer-1 Pallas kernels + pure-jnp oracles (build-time only)."""

from . import ref  # noqa: F401
from .sdca_kernels import (  # noqa: F401
    BUCKET_B,
    TILE_D,
    TILE_M,
    bucket_sdca_step,
    logloss_metrics,
    matvec,
    vmem_bytes_estimate,
)
