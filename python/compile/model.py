"""Layer-2 JAX compute graphs, composed from the Layer-1 Pallas kernels.

These are the functions the AOT pipeline (:mod:`compile.aot`) lowers to HLO
text for the rust runtime. Shapes are fixed at lowering time to the
canonical tiles in :mod:`compile.kernels.sdca_kernels`; the rust side pads
and composes tiles (see ``rust/src/runtime``).

Python in this package runs at *build time only* — nothing here is imported
on the training path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import sdca_kernels as k


def eval_tile(x, y, mask, w):
    """Loss/accuracy partials of one (TILE_M, TILE_D) example tile.

    Returns a 3-vector ``[loss_sum, correct, count]`` — the rust runtime
    accumulates these across example tiles. Feature-tiled datasets
    (d > TILE_D) instead use :func:`matvec_tile` per feature tile, sum the
    partial margins in rust, and finish with :func:`loss_tile`.
    """
    z = k.matvec(x, w)
    return (k.logloss_metrics(z, y, mask),)


def matvec_tile(x, w):
    """Partial margins of one (TILE_M, TILE_D) tile: ``z += X·w_tile``."""
    return (k.matvec(x, w),)


def loss_tile(z, y, mask):
    """Finish the reduction for pre-computed margins."""
    return (k.logloss_metrics(z, y, mask),)


def grad_tile(x, y, mask, w):
    """Logistic-loss gradient partials of one tile (L-BFGS/SAG baselines).

    Returns ``(grad_partial[TILE_D], loss_sum)`` where
    ``grad_partial = Xᵀ(−y·σ(−y·z)·mask)`` — the *unregularized,
    unnormalized* loss gradient; rust adds ``λw`` and divides by ``n``
    after accumulating tiles.
    """
    z = k.matvec(x, w)
    s = jax.nn.sigmoid(-y * z)  # = 1/(1+e^{yz})
    coeff = -y * s * mask
    grad = x.T @ coeff
    margin = -y * z
    loss = jnp.where(margin > 30.0, margin, jnp.log1p(jnp.exp(jnp.minimum(margin, 30.0))))
    return grad, jnp.sum(loss * mask)


def bucket_step(x, y, alpha, nsq, v, scalars):
    """One SDCA bucket update (kernel passthrough, see ``bucket_sdca_step``)."""
    return k.bucket_sdca_step(x, y, alpha, nsq, v, scalars)


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


#: artifact name → (function, example-argument factory). Everything the AOT
#: pipeline ships to the rust runtime is declared here.
ARTIFACTS = {
    "eval_tile": (
        eval_tile,
        lambda: (_f32(k.TILE_M, k.TILE_D), _f32(k.TILE_M), _f32(k.TILE_M), _f32(k.TILE_D)),
    ),
    "matvec_tile": (matvec_tile, lambda: (_f32(k.TILE_M, k.TILE_D), _f32(k.TILE_D))),
    "loss_tile": (loss_tile, lambda: (_f32(k.TILE_M), _f32(k.TILE_M), _f32(k.TILE_M))),
    "grad_tile": (
        grad_tile,
        lambda: (_f32(k.TILE_M, k.TILE_D), _f32(k.TILE_M), _f32(k.TILE_M), _f32(k.TILE_D)),
    ),
    "bucket_step": (
        bucket_step,
        lambda: (
            _f32(k.BUCKET_B, k.TILE_D),
            _f32(k.BUCKET_B),
            _f32(k.BUCKET_B),
            _f32(k.BUCKET_B),
            _f32(k.TILE_D),
            _f32(4),
        ),
    ),
}
