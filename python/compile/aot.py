"""AOT pipeline: lower every Layer-2 function to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README.md).

Usage (normally via ``make artifacts``)::

    cd python && python -m compile.aot --out ../artifacts

Writes ``<name>.hlo.txt`` per entry in :data:`compile.model.ARTIFACTS` plus
``manifest.json`` recording the argument/result shapes the rust runtime
validates against.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the
    rust side always unwraps a tuple, even for single-output functions)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(name: str):
    """Lower one registered artifact; returns (hlo_text, manifest entry)."""
    fn, example = model.ARTIFACTS[name]
    args = example()
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    out_shapes = [
        {"shape": list(s.shape), "dtype": str(s.dtype)}
        for s in jax.tree_util.tree_leaves(
            jax.eval_shape(fn, *args)
        )
    ]
    entry = {
        "inputs": [{"shape": list(a.shape), "dtype": str(a.dtype)} for a in args],
        "outputs": out_shapes,
    }
    return text, entry


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="output directory")
    p.add_argument(
        "--only",
        nargs="*",
        default=None,
        help="subset of artifact names (default: all registered)",
    )
    args = p.parse_args()
    os.makedirs(args.out, exist_ok=True)
    names = args.only or list(model.ARTIFACTS)
    manifest = {}
    for name in names:
        text, entry = lower_artifact(name)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = entry
        print(f"  {name}: {len(text)} chars -> {path}")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {len(names)} artifacts + manifest.json to {args.out}")


if __name__ == "__main__":
    main()
