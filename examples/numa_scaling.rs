//! NUMA-awareness walkthrough: thread placement, the hierarchical solver's
//! convergence at increasing (virtual) thread counts, and the cost model's
//! per-epoch breakdown on the paper's 4-node Xeon.
//!
//! ```bash
//! cargo run --release --example numa_scaling
//! ```

use parlin::data::synthetic;
use parlin::figures::DsKind;
use parlin::glm::Objective;
use parlin::metrics::Table;
use parlin::simcost::{epoch_time, xeon4, CostOpts, SolverKind};
use parlin::solver::{Partitioning, SolverConfig};
use parlin::sysinfo::Topology;
use parlin::vthread;

fn main() {
    let machine = xeon4();
    let topo: &Topology = &machine.topology;

    println!("== thread placement policy (§3) on {} ==", machine.name);
    let mut t1 = Table::new(&["threads", "placement (threads per node)"]);
    for threads in [1usize, 4, 8, 12, 16, 32] {
        t1.row(&[threads.to_string(), format!("{:?}", topo.place_threads(threads))]);
    }
    print!("{}", t1.render());

    println!("\n== hierarchical solver: epochs vs virtual threads (dense 20k × 100) ==");
    let ds = synthetic::dense_classification(20_000, 100, 42);
    let obj = Objective::Logistic { lambda: 1.0 / ds.n() as f64 };
    let mut t2 = Table::new(&["threads", "epochs", "gap", "converged"]);
    for threads in [1usize, 4, 8, 16, 32] {
        let cfg = SolverConfig::new(obj)
            .with_threads(threads)
            .with_partition(Partitioning::Dynamic)
            .with_tol(1e-4);
        let out = if threads == 1 {
            parlin::solver::seq::train_sequential(&ds, &cfg)
        } else {
            vthread::train_numa_sim(&ds, &cfg, topo)
        };
        t2.row(&[
            threads.to_string(),
            out.epochs_run.to_string(),
            format!("{:.2e}", out.final_gap),
            out.converged.to_string(),
        ]);
    }
    print!("{}", t2.render());
    println!("(dynamic partitioning keeps the epoch count near-sequential — the paper's point)");

    println!("\n== modeled per-epoch breakdown at paper scale (criteo-like) ==");
    let w = DsKind::CriteoLike.paper_workload();
    let mut t3 = Table::new(&[
        "threads", "compute", "stream", "alpha", "shared", "shuffle", "merge", "reduce", "total",
    ]);
    for threads in [1usize, 8, 16, 32] {
        let mut o = CostOpts::new(threads);
        o.bucket_size = 8;
        o.numa_aware = true;
        let kind = if threads <= 8 {
            SolverKind::Domesticated(Partitioning::Dynamic)
        } else {
            SolverKind::Numa(Partitioning::Dynamic)
        };
        let b = epoch_time(&machine, &w, kind, &o);
        let f = |x: f64| format!("{x:.3}");
        t3.row(&[
            threads.to_string(),
            f(b.compute),
            f(b.stream),
            f(b.alpha),
            f(b.shared),
            f(b.shuffle),
            f(b.merge),
            f(b.reduce),
            f(b.total()),
        ]);
    }
    print!("{}", t3.render());
}
