//! Host-measured solver comparison (a wall-clock companion to Figure 6).
//!
//! Trains logistic regression on a scaled dataset with every solver in the
//! repo — the paper's SDCA variants and the four baseline classes — and
//! reports measured time, passes and test loss *on this machine* (no cost
//! model involved; thread counts limited by the host's cores).
//!
//! ```bash
//! cargo run --release --example solver_comparison [-- <dataset-kind>]
//! ```

use parlin::baselines::{dual_cd, h2o_auto, lbfgs, sag, BaselineConfig};
use parlin::figures::DsKind;
use parlin::glm::{test_loss, Objective};
use parlin::metrics::Table;
use parlin::solver::{train, SolverConfig, Variant};
use parlin::with_ds;

fn main() {
    let kind = match std::env::args().nth(1).as_deref() {
        Some("criteo-like") => DsKind::CriteoLike,
        Some("epsilon-like") => DsKind::EpsilonLike,
        Some("sparse-synth") => DsKind::SparseSynth,
        Some("dense-synth") | None => DsKind::DenseSynth,
        Some(other) => {
            eprintln!("unknown kind {other}, using dense-synth");
            DsKind::DenseSynth
        }
    };
    let (ds, test) = kind.make(true, 42).split(0.2, 7); // held-out 20%
    let lambda = 1.0 / ds.n() as f64;
    let obj = Objective::Logistic { lambda };
    println!(
        "dataset {} (n={}, d={}, nnz={})\n",
        kind.name(),
        ds.n(),
        ds.d(),
        ds.nnz()
    );

    let tl = |w: &[f64]| {
        with_ds!(&test, d => {
            let idx: Vec<usize> = (0..d.n()).collect();
            test_loss(d, &obj, w, &idx)
        })
    };

    let mut table = Table::new(&["solver", "passes", "wall_s", "test_loss"]);

    // --- this paper's solvers
    for (label, variant, threads) in [
        ("snap seq (buckets)", Variant::Sequential, 1usize),
        ("snap dom 2T", Variant::Domesticated, 2),
        ("snap numa 4T", Variant::Numa, 4),
        ("wild 2T", Variant::Wild, 2),
    ] {
        let cfg = SolverConfig::new(obj)
            .with_variant(variant)
            .with_threads(threads)
            .with_tol(1e-4);
        let out = with_ds!(&ds, d => train(d, &cfg));
        let w = out.weights(&obj);
        table.row(&[
            label.into(),
            out.epochs_run.to_string(),
            format!("{:.3}", out.record.total_wall_s),
            format!("{:.4}", tl(&w)),
        ]);
    }

    // --- baseline classes
    let bcfg = BaselineConfig::new(obj).with_tol(1e-6).with_max_epochs(200);
    let runs = vec![
        ("liblinear (dual CD)", with_ds!(&ds, d => dual_cd::train_dual_cd(d, &bcfg))),
        ("lbfgs", with_ds!(&ds, d => lbfgs::train_lbfgs(d, &bcfg))),
        ("sag", with_ds!(&ds, d => sag::train_sag(d, &bcfg))),
        ("h2o auto", with_ds!(&ds, d => h2o_auto(d, &bcfg))),
    ];
    for (label, out) in runs {
        table.row(&[
            label.into(),
            out.record.epochs_run().to_string(),
            format!("{:.3}", out.record.total_wall_s),
            format!("{:.4}", tl(&out.w)),
        ]);
    }

    print!("{}", table.render());
    println!("\n(single-core host: thread counts here exercise correctness, not speedup —");
    println!(" the Figure 3/6 harnesses model the paper's 32-core testbeds.)");
}
