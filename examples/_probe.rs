use parlin::data::AnyDataset;
use parlin::figures::*;
use parlin::sysinfo::Topology;
use parlin::vthread::WildSimParams;

fn main() {
    let args: Vec<f64> = std::env::args().skip(1).map(|s| s.parse().unwrap()).collect();
    let pr = args[0];
    for kind in [
        DsKind::DenseSynth,
        DsKind::SparseSynth,
        DsKind::CriteoLike,
        DsKind::HiggsLike,
    ] {
        let ds: AnyDataset = kind.make(false, 42);
        for t in [8usize, 16, 32] {
            let topo = Topology::uniform(4, 8);
            let params = WildSimParams {
                p_collide_local: 0.0,
                p_collide_remote: pr,
                topology: topo,
            };
            let cfg = parlin::solver::SolverConfig::new(parlin::glm::Objective::Logistic {
                lambda: 10.0 / ds.n() as f64,
            })
            .with_threads(t)
            .with_tol(1e-3)
            .with_max_epochs(400)
            .with_seed(42);
            let out = parlin::with_ds!(&ds, d => parlin::vthread::train_wild_sim(d, &cfg, &params));
            let rel = out.final_gap / out.final_primal.max(1e-12);
            print!(
                "  T={t}: ep={} rg={:.3}{}",
                out.epochs_run,
                rel,
                if rel < 0.05 { "" } else { "(WRONG)" }
            );
        }
        println!("  <- {}", kind.name());
    }
}
