//! End-to-end driver over the full three-layer stack (DESIGN.md §7).
//!
//! Workload: the paper's §2 dense synthetic dataset (100k × 100, Fig. 1a)
//! plus a held-out test split. The run proves all layers compose:
//!
//! 1. **L3 rust coordinator** trains with the paper's solver (buckets +
//!    dynamic partitioning), logging per-epoch state;
//! 2. after every epoch, train/test loss and accuracy are evaluated
//!    through the **AOT artifacts** (L2 JAX graph calling the L1 Pallas
//!    matvec/loss kernels) executed via PJRT — Python never runs;
//! 3. a second model is trained entirely through the `bucket_step` HLO
//!    artifact (L1 kernel in the inner loop) and checked against the
//!    native model;
//! 4. the loss curve lands in `artifacts/e2e_loss_curve.csv` and the final
//!    duality gap is asserted < 1e-3.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train
//! ```

use parlin::data::{split_indices, synthetic};
use parlin::glm::{duality_gap, Objective};
use parlin::runtime::{hlo_trainer, ArtifactRuntime, TiledEvaluator};
use parlin::solver::{BucketPolicy, Partitioning, SolverConfig, Variant};
use parlin::util::Timer;
use std::fmt::Write as _;

fn main() -> anyhow::Result<()> {
    let t_all = Timer::start();
    println!("[1/4] loading AOT artifacts (PJRT CPU client)…");
    let rt = ArtifactRuntime::load_default()?;
    rt.validate_tiles()?;
    println!("      artifacts: {:?}", rt.names());

    println!("[2/4] generating the paper's dense synthetic workload (100k × 100)…");
    let ds = synthetic::dense_classification(100_000, 100, 42);
    let (train_idx, test_idx) = split_indices(ds.n(), 0.2, 7);
    let obj = Objective::Logistic {
        lambda: 1.0 / train_idx.len() as f64,
    };
    // tile the evaluation sets once; per-epoch cost is just PJRT dispatches
    let ev_train = TiledEvaluator::new(&rt, &ds, &train_idx[..20_000.min(train_idx.len())])?;
    let ev_test = TiledEvaluator::new(&rt, &ds, &test_idx)?;

    println!("[3/4] training (L3 coordinator, epoch metrics via L2/L1 artifacts)…");
    // Epoch-wise snapshots: the solver is deterministic, so the model after
    // k epochs equals a fresh run with max_epochs = k and the same seed.
    // We rerun per epoch (cheap at this scale) and push every snapshot
    // through the HLO evaluator.
    let mut csv = String::from("epoch,train_loss,test_loss,test_acc,gap,epoch_wall_s\n");
    let full_cfg = SolverConfig::new(obj)
        .with_variant(Variant::Domesticated)
        .with_threads(4)
        .with_partition(Partitioning::Dynamic)
        .with_bucket(BucketPolicy::Fixed(8));
    let mut epochs_run = 0;
    let mut last_gap = f64::INFINITY;
    let mut prev_alpha: Vec<f64> = Vec::new();
    let max_epochs = 30;
    let train_ds = &ds;
    for epoch in 1..=max_epochs {
        let t = Timer::start();
        let mut c = full_cfg.clone();
        c.max_epochs = epoch;
        c.tol = 0.0;
        let out = parlin::solver::train(train_ds, &c);
        let w = out.weights(&obj);
        let m_train = ev_train.eval(&w)?;
        let m_test = ev_test.eval(&w)?;
        let gap = duality_gap(train_ds, &obj, &out.state).gap;
        prev_alpha = out.state.alpha.clone();
        let _ = writeln!(
            csv,
            "{epoch},{:.6},{:.6},{:.4},{:.6e},{:.3}",
            m_train.mean_loss,
            m_test.mean_loss,
            m_test.accuracy,
            gap,
            t.elapsed_s()
        );
        println!(
            "      epoch {epoch:>2}: train {:.5}  test {:.5}  acc {:.4}  gap {:.2e}",
            m_train.mean_loss, m_test.mean_loss, m_test.accuracy, gap
        );
        epochs_run = epoch;
        last_gap = gap;
        // stop on the duality-gap certificate (robust to epochs the
        // adaptive-σ′ solver backtracks, which leave the model unchanged)
        if gap < 1e-4 {
            break;
        }
    }
    let _ = &prev_alpha;
    std::fs::write("artifacts/e2e_loss_curve.csv", &csv)?;
    println!("      loss curve -> artifacts/e2e_loss_curve.csv");
    assert!(
        last_gap < 1e-3,
        "final duality gap {last_gap:.3e} must be < 1e-3"
    );

    println!("[4/4] HLO-kernel-in-the-loop trainer (bucket_step artifact)…");
    let small = synthetic::dense_classification(4_000, 100, 43);
    let hcfg = SolverConfig::new(Objective::Logistic { lambda: 1.0 / 4_000.0 })
        .with_tol(1e-4)
        .with_max_epochs(60);
    let hlo_out = hlo_trainer::train_hlo_bucketed(&rt, &small, &hcfg)?;
    let native = parlin::solver::train(&small, &hcfg.clone().with_variant(Variant::Sequential));
    let dist = parlin::util::rel_change(
        &native.weights(&hcfg.obj),
        &hlo_out.weights(&hcfg.obj),
    );
    println!(
        "      hlo-bucket: {} epochs, gap {:.2e}; ‖w_hlo − w_native‖/‖w‖ = {dist:.2e}",
        hlo_out.epochs_run, hlo_out.final_gap
    );
    assert!(dist < 5e-2, "HLO and native solutions diverged: {dist}");

    println!(
        "\nE2E OK: {epochs_run} epochs, final gap {last_gap:.2e}, total {:.1}s — all three layers compose.",
        t_all.elapsed_s()
    );
    Ok(())
}
