//! Validate a `--tune-log` output file with the library's own reader:
//! the file must carry the `# parlin-tune-v1` magic, parse back into a
//! [`TuneLog`], and re-render byte-for-byte identical CSV. With the
//! matching `--convergence-log` trace supplied, the trace is replayed
//! through a fresh tuner and every recorded decision must be reproduced
//! — the "decisions are a pure function of (seed, observation stream)"
//! contract, checked from outside the process that made them. CI runs
//! this against a short tuned `parlin train` run:
//!
//! ```bash
//! cargo run --release --example check_tune -- TUNE_train.csv \
//!     --trace CONV_train.csv
//! ```
//!
//! Exits nonzero with a message naming the first divergence found.

use anyhow::{anyhow, bail, Result};
use parlin::obs::ConvergenceTrace;
use parlin::solver::{TuneLog, TUNE_LOG_MAGIC};

fn main() {
    if let Err(e) = run() {
        eprintln!("check_tune: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (log_path, trace_path) = parse_args(&args)?;

    let text =
        std::fs::read_to_string(&log_path).map_err(|e| anyhow!("reading {log_path}: {e}"))?;
    if !text.starts_with(TUNE_LOG_MAGIC) {
        bail!("{log_path} does not start with the `{TUNE_LOG_MAGIC}` magic — not a tune log");
    }
    let log = TuneLog::from_csv(&text)
        .ok_or_else(|| anyhow!("{log_path}: malformed tune-log csv (header or row failed to parse)"))?;

    // Round trip: parse → re-render must reproduce the file byte-for-byte.
    // Anything else means the reader and writer disagree, and a replayed
    // log could no longer be diffed against the original with `cmp`.
    let round = log.to_csv();
    if round != text {
        let diverged = text
            .lines()
            .zip(round.lines())
            .enumerate()
            .find(|(_, (a, b))| a != b);
        match diverged {
            Some((i, (file, render))) => bail!(
                "{log_path}: csv does not round-trip — line {} reads {file:?} \
                 but re-renders as {render:?}",
                i + 1
            ),
            None => bail!(
                "{log_path}: csv does not round-trip — file has {} line(s), \
                 re-render has {}",
                text.lines().count(),
                round.lines().count()
            ),
        }
    }

    let mut replayed = String::new();
    if let Some(trace_path) = trace_path {
        let ttext = std::fs::read_to_string(&trace_path)
            .map_err(|e| anyhow!("reading {trace_path}: {e}"))?;
        let trace = ConvergenceTrace::from_csv(&ttext)
            .ok_or_else(|| anyhow!("{trace_path}: malformed convergence-trace csv"))?;
        if trace.solver != log.solver {
            bail!(
                "solver mismatch: {log_path} was recorded by {:?} but {trace_path} \
                 traces {:?} — these artifacts are not from the same run",
                log.solver,
                trace.solver
            );
        }
        log.verify_replay(&trace.points).map_err(|e| {
            anyhow!("{log_path}: replay against {trace_path} diverged — {e}")
        })?;
        replayed = format!(", replayed {} trace point(s) exactly", trace.points.len());
    }

    let caps = &log.init.caps;
    let caps_str = ["bucket", "layout", "workers"]
        .iter()
        .zip([caps.bucket, caps.layout, caps.workers])
        .filter_map(|(n, on)| on.then_some(*n))
        .collect::<Vec<_>>()
        .join(",");
    println!(
        "check_tune: OK — {} decision(s) by {} (seed {}, window {}, caps [{}]){}",
        log.decisions.len(),
        log.solver,
        log.init.seed,
        log.init.window,
        if caps_str.is_empty() { "none" } else { &caps_str },
        replayed
    );
    Ok(())
}

/// `<tune-log.csv> [--trace <convergence.csv>]`.
fn parse_args(args: &[String]) -> Result<(String, Option<String>)> {
    let mut log_path = None;
    let mut trace_path = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--trace" => {
                let p = args
                    .get(i + 1)
                    .ok_or_else(|| anyhow!("--trace needs a convergence-log csv path"))?;
                if trace_path.replace(p.to_string()).is_some() {
                    bail!("--trace given twice");
                }
                i += 2;
            }
            p if log_path.is_none() => {
                log_path = Some(p.to_string());
                i += 1;
            }
            other => bail!("unexpected argument '{other}'"),
        }
    }
    let log_path = log_path.ok_or_else(|| {
        anyhow!("usage: check_tune <tune-log.csv> [--trace <convergence.csv>]")
    })?;
    Ok((log_path, trace_path))
}
