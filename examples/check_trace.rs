//! Validate a `--trace` output file: well-formed JSON (checked by a
//! hand-rolled parser — the offline toolchain has no serde), per-tid
//! monotonic timestamps for the instant events, and presence of required
//! event groups. CI runs this against a short `parlin serve --trace` run:
//!
//! ```bash
//! cargo run --release --example check_trace -- trace.json \
//!     --require job,epoch,publish,reject,drain,rollback
//! ```
//!
//! Exits nonzero with a message on the first violation found.

use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

fn main() {
    if let Err(e) = run() {
        eprintln!("check_trace: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (path, required) = parse_args(&args)?;
    let text = std::fs::read_to_string(&path).map_err(|e| anyhow!("reading {path}: {e}"))?;

    let root = Json::parse(&text)?;
    let events = root
        .get("traceEvents")
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow!("top-level object has no \"traceEvents\" array"))?;

    let mut group_counts: HashMap<&'static str, u64> = HashMap::new();
    let mut last_ts: HashMap<i64, f64> = HashMap::new();
    let mut instants = 0u64;
    for (i, ev) in events.iter().enumerate() {
        let ph = ev
            .get("ph")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("event {i} has no \"ph\" phase"))?;
        if ph != "i" {
            continue; // metadata records ("M") carry no timestamp
        }
        instants += 1;
        let name = ev
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("instant event {i} has no \"name\""))?;
        let tid = ev
            .get("tid")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("instant event {i} ({name}) has no numeric \"tid\""))?;
        let tid = tid as i64;
        let ts = ev
            .get("ts")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("instant event {i} ({name}) has no numeric \"ts\""))?;
        if let Some(prev) = last_ts.insert(tid, ts) {
            if ts < prev {
                bail!(
                    "event {i} ({name}) on tid {tid} goes back in time: \
                     ts {ts} after {prev} (per-thread streams must be FIFO)"
                );
            }
        }
        if let Some(group) = group_of(name) {
            *group_counts.entry(group).or_insert(0) += 1;
        }
    }

    for group in &required {
        let n = group_counts.get(group.as_str()).copied().unwrap_or(0);
        if n == 0 {
            bail!(
                "required event group '{group}' is absent \
                 (groups seen: {group_counts:?})"
            );
        }
    }

    let mut groups: Vec<_> = group_counts.iter().collect();
    groups.sort();
    println!(
        "check_trace: OK — {} instant events on {} threads, groups {groups:?}",
        instants,
        last_ts.len()
    );
    Ok(())
}

/// `<path> [--require a,b,c]` — the groups map onto the event vocabulary
/// of `parlin::obs::EventKind` (see `docs/OBSERVABILITY.md`).
fn parse_args(args: &[String]) -> Result<(String, Vec<String>)> {
    let mut path = None;
    let mut required = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--require" => {
                let list = args
                    .get(i + 1)
                    .ok_or_else(|| anyhow!("--require needs a comma-separated group list"))?;
                for g in list.split(',').filter(|g| !g.is_empty()) {
                    if group_names().iter().all(|(_, name)| *name != g) {
                        bail!(
                            "unknown group '{g}' \
                             (known: job, epoch, publish, reject, drain, rollback)"
                        );
                    }
                    required.push(g.to_string());
                }
                i += 2;
            }
            p if path.is_none() => {
                path = Some(p.to_string());
                i += 1;
            }
            other => bail!("unexpected argument '{other}'"),
        }
    }
    let path = path.ok_or_else(|| {
        anyhow!(
            "usage: check_trace <trace.json> \
             [--require job,epoch,publish,reject,drain,rollback]"
        )
    })?;
    Ok((path, required))
}

fn group_names() -> &'static [(&'static str, &'static str)] {
    &[
        ("job_enqueue", "job"),
        ("job_start", "job"),
        ("job_finish", "job"),
        ("epoch_begin", "epoch"),
        ("epoch_end", "epoch"),
        ("snapshot_publish", "publish"),
        ("admission_reject", "reject"),
        ("ingest_drain", "drain"),
        ("snapshot_rollback", "rollback"),
    ]
}

fn group_of(event_name: &str) -> Option<&'static str> {
    group_names().iter().find(|(ev, _)| *ev == event_name).map(|(_, g)| *g)
}

// ---------------------------------------------------------------------------
// Minimal strict JSON value + recursive-descent parser. Rejects trailing
// garbage, unterminated strings, bad escapes and malformed numbers — the
// "well-formedness" half of the trace check.
// ---------------------------------------------------------------------------

#[derive(Debug)]
enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while matches!(self.bytes.get(self.pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            other => bail!("unexpected {other:?} at byte {}", self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            fields.push((key, self.value()?));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                other => bail!("expected ',' or '}}' at byte {}, found {other:?}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected ',' or ']' at byte {}, found {other:?}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string at byte {}", self.pos),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            let ch = char::from_u32(code)
                                .ok_or_else(|| anyhow!("invalid \\u{code:04x} escape"))?;
                            out.push(ch);
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?} at byte {}", self.pos),
                    }
                    self.pos += 1;
                }
                Some(c) if c < 0x20 => {
                    bail!("unescaped control byte 0x{c:02x} in string at byte {}", self.pos)
                }
                Some(_) => {
                    // multi-byte UTF-8 sequences pass through untouched
                    let start = self.pos;
                    self.pos += 1;
                    while self.bytes.get(self.pos).is_some_and(|b| b & 0xC0 == 0x80) {
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        match s.parse::<f64>() {
            Ok(x) => Ok(Json::Num(x)),
            Err(e) => bail!("malformed number '{s}' at byte {start}: {e}"),
        }
    }
}
