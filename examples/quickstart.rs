//! Quickstart: train a logistic model on synthetic data with the paper's
//! solver and inspect convergence.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use parlin::data::synthetic;
use parlin::glm::{accuracy, duality_gap, Objective};
use parlin::solver::{train, SolverConfig, Variant};

fn main() {
    // the paper's §2 dense synthetic workload, scaled to run in seconds
    let ds = synthetic::dense_classification(20_000, 100, 42);
    let obj = Objective::Logistic {
        lambda: 1.0 / ds.n() as f64,
    };

    println!("== sequential (bucketed) ==");
    let cfg = SolverConfig::new(obj).with_tol(1e-4);
    let out = train(&ds, &cfg);
    report(&ds, &obj, &out);

    println!("\n== domesticated, 4 threads, dynamic partitioning ==");
    let cfg = SolverConfig::new(obj)
        .with_variant(Variant::Domesticated)
        .with_threads(4)
        .with_tol(1e-4);
    let out = train(&ds, &cfg);
    report(&ds, &obj, &out);

    println!("\n== wild baseline, 4 threads (what the paper improves on) ==");
    let cfg = SolverConfig::new(obj)
        .with_variant(Variant::Wild)
        .with_threads(4)
        .with_tol(1e-4);
    let out = train(&ds, &cfg);
    report(&ds, &obj, &out);
}

fn report(
    ds: &parlin::data::Dataset<parlin::data::DenseMatrix>,
    obj: &Objective,
    out: &parlin::solver::TrainOutput,
) {
    let idx: Vec<usize> = (0..ds.n()).collect();
    let w = out.weights(obj);
    let gap = duality_gap(ds, obj, &out.state);
    println!(
        "{}: {} epochs in {:.2}s | primal {:.5} gap {:.2e} | train acc {:.4}",
        out.record.solver,
        out.epochs_run,
        out.record.total_wall_s,
        gap.primal,
        gap.gap,
        accuracy(ds, &w, &idx),
    );
}
