//! Scrape a live `--metrics-addr` endpoint and validate what it serves:
//! `/metrics` must be well-formed Prometheus text exposition (checked by a
//! hand-rolled line validator — the offline toolchain has no client
//! library) and `/health` must answer with a recognizable health line and
//! a matching status code. CI points this at a backgrounded
//! `parlin serve --metrics-addr 127.0.0.1:0` run:
//!
//! ```bash
//! cargo run --release --example check_metrics -- 127.0.0.1:9184 \
//!     --require sched,pool,solver
//! ```
//!
//! `--require` lists registry families (the dotted prefix before the
//! first `.`, e.g. `sched` for `sched.publishes`) that must each have at
//! least one sample — i.e. a `parlin_<family>_…` metric. Exits nonzero
//! with a message on the first violation found.
//!
//! Labelled series (`name{key="value"} value`) are held to the same
//! 0.0.4 rules: label names in `[a-zA-Z_][a-zA-Z0-9_]*`, values quoted
//! with only `\\`/`\"`/`\n` escapes, and — the part a registry bug would
//! actually break — at most ONE sample per (name, label-set) pair, with
//! label order canonicalised before comparing.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeSet;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

fn main() {
    if let Err(e) = run() {
        eprintln!("check_metrics: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (addr, required) = parse_args(&args)?;

    let (status, body) = http_get(&addr, "/metrics")?;
    if status != 200 {
        bail!("/metrics answered {status}, expected 200");
    }
    let (samples, labelled, families) = validate_prometheus(&body)?;
    for fam in &required {
        let name = format!("parlin_{fam}_");
        if !families.iter().any(|f| f.starts_with(&name)) {
            bail!(
                "required metric family '{fam}' has no samples \
                 (families seen: {families:?})"
            );
        }
    }

    let (status, health) = http_get(&addr, "/health")?;
    let health = health.trim_end();
    match (status, health) {
        (200, "Healthy") => {}
        (503, h) if h.starts_with("Degraded (") && h.ends_with(')') => {}
        _ => bail!(
            "/health answered {status} {health:?} — expected \
             200 \"Healthy\" or 503 \"Degraded (<reason>)\""
        ),
    }

    println!(
        "check_metrics: OK — {} samples ({} labelled) across {} metrics on {}, \
         health {status} {health}",
        samples,
        labelled,
        families.len(),
        addr
    );
    Ok(())
}

/// `<host:port> [--require sched,pool,solver]`.
fn parse_args(args: &[String]) -> Result<(String, Vec<String>)> {
    let mut addr = None;
    let mut required = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--require" => {
                let list = args
                    .get(i + 1)
                    .ok_or_else(|| anyhow!("--require needs a comma-separated family list"))?;
                for f in list.split(',').filter(|f| !f.is_empty()) {
                    if !f.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
                        bail!("family '{f}' is not a bare registry prefix (e.g. sched)");
                    }
                    required.push(f.to_string());
                }
                i += 2;
            }
            a if addr.is_none() => {
                addr = Some(a.to_string());
                i += 1;
            }
            other => bail!("unexpected argument '{other}'"),
        }
    }
    let addr = addr.ok_or_else(|| {
        anyhow!("usage: check_metrics <host:port> [--require sched,pool,solver]")
    })?;
    Ok((addr, required))
}

/// One plain HTTP/1.0 GET — the endpoint closes the connection after the
/// response, so "read to EOF" is the framing.
fn http_get(addr: &str, path: &str) -> Result<(u16, String)> {
    let mut s = TcpStream::connect(addr).map_err(|e| anyhow!("connecting to {addr}: {e}"))?;
    s.set_read_timeout(Some(Duration::from_secs(5)))?;
    s.set_write_timeout(Some(Duration::from_secs(5)))?;
    write!(s, "GET {path} HTTP/1.0\r\nHost: {addr}\r\n\r\n")?;
    let mut text = String::new();
    s.read_to_string(&mut text)
        .map_err(|e| anyhow!("reading {path} from {addr}: {e}"))?;
    let status: u16 = text
        .lines()
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|c| c.parse().ok())
        .ok_or_else(|| anyhow!("{path}: malformed status line in {text:?}"))?;
    let body = text
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .ok_or_else(|| anyhow!("{path}: response has no header/body separator"))?;
    Ok((status, body))
}

/// Validate Prometheus text exposition (version 0.0.4) line by line:
/// comments are `# TYPE` / `# HELP`, every other non-empty line is
/// `name[{labels}] value` — one value, clean charsets, parseable number,
/// and at most one sample per (name, canonicalised label-set) series.
/// Returns (sample count, labelled sample count, distinct sample names).
fn validate_prometheus(body: &str) -> Result<(usize, usize, BTreeSet<String>)> {
    let mut samples = 0usize;
    let mut labelled = 0usize;
    let mut names = BTreeSet::new();
    let mut series: BTreeSet<(String, Vec<(String, String)>)> = BTreeSet::new();
    for (lineno, line) in body.lines().enumerate() {
        let lineno = lineno + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(comment) = line.strip_prefix('#') {
            let mut words = comment.split_whitespace();
            match words.next() {
                Some("TYPE") => {
                    let name = words
                        .next()
                        .ok_or_else(|| anyhow!("line {lineno}: # TYPE without a name"))?;
                    check_name(name, lineno)?;
                    match words.next() {
                        Some("counter" | "gauge" | "summary" | "histogram" | "untyped") => {}
                        other => bail!("line {lineno}: bad TYPE kind {other:?}"),
                    }
                }
                Some("HELP") => {}
                other => bail!("line {lineno}: unknown comment {other:?}"),
            }
            continue;
        }
        let (metric, value) = line
            .rsplit_once(' ')
            .ok_or_else(|| anyhow!("line {lineno}: no space before the sample value"))?;
        if value.parse::<f64>().is_err() && !matches!(value, "+Inf" | "-Inf" | "NaN") {
            bail!("line {lineno}: sample value {value:?} is not a number");
        }
        let (name, pairs) = match metric.split_once('{') {
            None => (metric, Vec::new()),
            Some((name, rest)) => {
                let labels = rest
                    .strip_suffix('}')
                    .ok_or_else(|| anyhow!("line {lineno}: unterminated label set"))?;
                labelled += 1;
                (name, check_labels(labels, lineno)?)
            }
        };
        check_name(name, lineno)?;
        // label order is presentation, identity is the sorted pair list:
        // a second sample for the same series means the scrape would be
        // ingested as two conflicting writes
        let mut key = pairs;
        key.sort();
        if !series.insert((name.to_string(), key)) {
            bail!(
                "line {lineno}: duplicate series {metric:?} — \
                 one sample per (name, label set)"
            );
        }
        samples += 1;
        names.insert(name.to_string());
    }
    Ok((samples, labelled, names))
}

fn check_name(name: &str, lineno: usize) -> Result<()> {
    let mut chars = name.chars();
    let ok_first = chars
        .next()
        .is_some_and(|c| c.is_ascii_alphabetic() || c == '_' || c == ':');
    if !ok_first || !chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':') {
        bail!("line {lineno}: bad metric name {name:?}");
    }
    Ok(())
}

/// `key="value",key="value"` — quoted values with `\\`, `\"` and `\n`
/// escapes, label names in `[a-zA-Z_][a-zA-Z0-9_]*`. Returns the parsed
/// (name, raw quoted value) pairs so the caller can canonicalise the
/// label set for duplicate-series detection.
fn check_labels(labels: &str, lineno: usize) -> Result<Vec<(String, String)>> {
    let b = labels.as_bytes();
    let mut pairs = Vec::new();
    let mut i = 0;
    loop {
        let start = i;
        while i < b.len() && b[i] != b'=' {
            i += 1;
        }
        let key = &labels[start..i];
        let mut chars = key.chars();
        let ok_first = chars.next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_');
        if !ok_first || !chars.all(|c| c.is_ascii_alphanumeric() || c == '_') {
            bail!("line {lineno}: bad label name {key:?}");
        }
        if i >= b.len() {
            bail!("line {lineno}: label {key:?} has no value");
        }
        i += 1; // '='
        if b.get(i) != Some(&b'"') {
            bail!("line {lineno}: label {key:?} value is not quoted");
        }
        i += 1;
        let vstart = i;
        loop {
            match b.get(i) {
                None => bail!("line {lineno}: unterminated label value for {key:?}"),
                Some(b'"') => {
                    i += 1;
                    break;
                }
                Some(b'\\') => match b.get(i + 1) {
                    Some(b'\\' | b'"' | b'n') => i += 2,
                    other => bail!("line {lineno}: bad escape {other:?} in label {key:?}"),
                },
                Some(_) => i += 1,
            }
        }
        pairs.push((key.to_string(), labels[vstart..i - 1].to_string()));
        match b.get(i) {
            None => return Ok(pairs),
            Some(b',') => i += 1,
            Some(&c) => bail!(
                "line {lineno}: expected ',' or end of labels, found {:?}",
                c as char
            ),
        }
    }
}
